//! Deterministic fault injection for the fleet simulator: seeded,
//! reproducible schedules of replica crashes, transient stragglers and
//! interconnect degradation, plus the front-end resilience knobs
//! (failover, capped-exponential retry, proactive drain) that decide
//! how the fleet degrades when they fire.
//!
//! Semantics (all pure `f64`/integer arithmetic on a fixed event
//! order, so a fixed schedule gives bit-identical metrics per run):
//!
//! * **Crash** — at `t_s` the replica's queued and running requests
//!   all fail, its `KvCache` is wiped wholesale (shared prefix
//!   included), and the replica is down for `recovery_s` seconds. A
//!   recovered replica rejoins cold: its first admissions re-lease KV
//!   and re-materialize the shared prefix, which *is* the warm-up
//!   cost. Failed requests are re-offered by the front end under the
//!   [`RetryPolicy`] (or counted permanently lost).
//! * **Straggler** — during `[t_s, t_s + duration_s)` every iteration
//!   *starting* on the replica has its costed latency multiplied by
//!   `slowdown` (>= 1). The multiplier is applied to the iteration
//!   latency after costing, not inside the shared `BatchCoster` memo:
//!   the memo is composition-keyed and shared across replicas, so
//!   scaling inside it would leak one replica's thermal throttle into
//!   its healthy peers. Energy is unchanged (a throttled clock does
//!   the same work, slower).
//! * **LinkDegrade** — during the window every KV handoff (front-end
//!   rebalancing and proactive drains) pays `factor` (>= 1) times the
//!   configured `handoff_s_per_token`. The link is fleet-wide.
//!
//! The whole layer is bitwise-free when disabled: an empty schedule
//! with retries off reproduces `simulate_fleet_frontend` bit for bit
//! (anchored in `rust/tests/fault_properties.rs`).

use crate::util::Rng;

/// What goes wrong.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The replica dies at `t_s`, losing queue, running set and KV
    /// cache; it accepts work again `recovery_s` seconds later.
    Crash { recovery_s: f64 },
    /// Iterations starting in `[t_s, t_s + duration_s)` run `slowdown`
    /// times slower (thermal throttling, noisy neighbors).
    Straggler { duration_s: f64, slowdown: f64 },
    /// KV handoffs during the window cost `factor` times the normal
    /// per-token link delay (fleet-wide; the `replica` field is
    /// ignored).
    LinkDegrade { duration_s: f64, factor: f64 },
}

impl FaultKind {
    /// Short label for trace instants (`sim::telemetry`) and logs.
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::Crash { .. } => "crash",
            FaultKind::Straggler { .. } => "straggler",
            FaultKind::LinkDegrade { .. } => "link",
        }
    }
}

/// One scheduled fault event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Target replica index (ignored for `LinkDegrade`).
    pub replica: usize,
    /// Injection time (s).
    pub t_s: f64,
    pub kind: FaultKind,
}

/// A reproducible fault schedule: an explicit list of [`FaultSpec`]s,
/// hand-built through the builder methods or generated from a seed.
/// The driver sorts events by `(t_s, insertion order)`, so the same
/// schedule value always replays identically.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultSchedule {
    pub faults: Vec<FaultSpec>,
}

impl FaultSchedule {
    /// No faults: the fault layer is bitwise-free with this schedule
    /// (and retries disabled).
    pub fn none() -> Self {
        FaultSchedule { faults: Vec::new() }
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Add a crash of `replica` at `t_s`, recovering `recovery_s`
    /// seconds later.
    pub fn crash(mut self, replica: usize, t_s: f64, recovery_s: f64) -> Self {
        self.faults.push(FaultSpec {
            replica,
            t_s: t_s.max(0.0),
            kind: FaultKind::Crash {
                recovery_s: recovery_s.max(0.0),
            },
        });
        self
    }

    /// Add a straggler window on `replica`: iterations starting in
    /// `[t_s, t_s + duration_s)` run `slowdown` (clamped >= 1) times
    /// slower.
    pub fn straggler(mut self, replica: usize, t_s: f64, duration_s: f64, slowdown: f64) -> Self {
        self.faults.push(FaultSpec {
            replica,
            t_s: t_s.max(0.0),
            kind: FaultKind::Straggler {
                duration_s: duration_s.max(0.0),
                slowdown: slowdown.max(1.0),
            },
        });
        self
    }

    /// Add a fleet-wide link-degradation window: KV handoffs in
    /// `[t_s, t_s + duration_s)` cost `factor` (clamped >= 1) times
    /// the configured per-token delay.
    pub fn link_degrade(mut self, t_s: f64, duration_s: f64, factor: f64) -> Self {
        self.faults.push(FaultSpec {
            replica: 0,
            t_s: t_s.max(0.0),
            kind: FaultKind::LinkDegrade {
                duration_s: duration_s.max(0.0),
                factor: factor.max(1.0),
            },
        });
        self
    }

    /// Seeded generator: `n_crashes` crashes (uniform in 20-70% of the
    /// horizon, recovering after 10-30% of it) and `n_stragglers`
    /// 2-4x slowdown windows (10-30% of the horizon long), targets
    /// drawn uniformly over `n_replicas`. Deterministic per seed — the
    /// reproducibility contract of every fault study.
    pub fn seeded(
        n_replicas: usize,
        horizon_s: f64,
        n_crashes: usize,
        n_stragglers: usize,
        seed: u64,
    ) -> Self {
        let n = n_replicas.max(1);
        let h = horizon_s.max(1e-9);
        let mut rng = Rng::seed_from_u64(seed ^ 0x6661_756c_7473); // "faults"
        let mut s = FaultSchedule::none();
        for _ in 0..n_crashes {
            let rep = rng.gen_index(n);
            let t = (0.2 + 0.5 * rng.gen_f64()) * h;
            let rec = (0.1 + 0.2 * rng.gen_f64()) * h;
            s = s.crash(rep, t, rec);
        }
        for _ in 0..n_stragglers {
            let rep = rng.gen_index(n);
            let t = (0.1 + 0.6 * rng.gen_f64()) * h;
            let dur = (0.1 + 0.2 * rng.gen_f64()) * h;
            let slow = 2.0 + 2.0 * rng.gen_f64();
            s = s.straggler(rep, t, dur, slow);
        }
        s
    }

    pub fn describe(&self) -> String {
        if self.is_empty() {
            return "no faults".into();
        }
        let mut crashes = 0usize;
        let mut stragglers = 0usize;
        let mut links = 0usize;
        for f in &self.faults {
            match f.kind {
                FaultKind::Crash { .. } => crashes += 1,
                FaultKind::Straggler { .. } => stragglers += 1,
                FaultKind::LinkDegrade { .. } => links += 1,
            }
        }
        format!("{crashes} crash + {stragglers} straggler + {links} link")
    }
}

/// Capped exponential backoff for failed/shed requests: attempt `k`'s
/// re-offer waits `base * mult^(k-1)` seconds, capped. A request gets
/// `max_attempts` offers in total (the original included); when they
/// are exhausted it is permanently lost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Total offers per request, the first included. `<= 1` disables
    /// retry: every failure is immediately lost (or finally shed).
    pub max_attempts: usize,
    /// Delay before the first retry (s).
    pub backoff_base_s: f64,
    /// Multiplier per further retry (clamped >= 1).
    pub backoff_mult: f64,
    /// Upper bound on any single backoff delay (s).
    pub backoff_cap_s: f64,
}

impl RetryPolicy {
    /// No retries: failures are immediately lost. With this (and an
    /// empty schedule) the fault layer is bitwise-free.
    pub fn disabled() -> Self {
        RetryPolicy {
            max_attempts: 1,
            backoff_base_s: 0.0,
            backoff_mult: 2.0,
            backoff_cap_s: 0.0,
        }
    }

    /// Capped exponential backoff, doubling per attempt.
    pub fn capped(max_attempts: usize, backoff_base_s: f64, backoff_cap_s: f64) -> Self {
        RetryPolicy {
            max_attempts: max_attempts.max(1),
            backoff_base_s: backoff_base_s.max(0.0),
            backoff_mult: 2.0,
            backoff_cap_s: backoff_cap_s.max(0.0),
        }
    }

    pub fn enabled(&self) -> bool {
        self.max_attempts > 1
    }

    /// Backoff before retry number `retry_no` (1-based: the delay
    /// between the first failure and the second offer is
    /// `delay_s(1) = base`). Computed by repeated multiplication, not
    /// `powf`, so the schedule is exactly reproducible.
    pub fn delay_s(&self, retry_no: usize) -> f64 {
        let base = self.backoff_base_s.max(0.0);
        let cap = self.backoff_cap_s.max(base);
        let mut d = base;
        for _ in 1..retry_no.max(1) {
            d = (d * self.backoff_mult.max(1.0)).min(cap);
        }
        d.min(cap)
    }

    pub fn describe(&self) -> String {
        if self.enabled() {
            format!(
                "retry x{} ({:.3}s base, {:.3}s cap)",
                self.max_attempts - 1,
                self.backoff_base_s,
                self.backoff_cap_s
            )
        } else {
            "no retry".into()
        }
    }
}

/// Proactive evacuation ahead of a *scheduled* crash (planned
/// maintenance, predicted failure): `lead_s` before each crash the
/// front end migrates up to `max_requests` mid-decode requests off the
/// doomed replica over the drain link, reusing the rebalancer's
/// block-rounded KV handoff. Requests still prefilling (or queued)
/// cannot be drained — they die with the replica and take the retry
/// path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DrainSpec {
    /// How long before the scheduled crash the drain starts (s).
    pub lead_s: f64,
    /// Drain-link KV handoff cost per block-rounded token (s/token),
    /// scaled by any active `LinkDegrade` window.
    pub handoff_s_per_token: f64,
    /// At most this many requests evacuated per drain event.
    pub max_requests: usize,
}

impl DrainSpec {
    pub fn new(lead_s: f64, handoff_s_per_token: f64, max_requests: usize) -> Self {
        DrainSpec {
            lead_s: lead_s.max(0.0),
            handoff_s_per_token: handoff_s_per_token.max(0.0),
            max_requests: max_requests.max(1),
        }
    }
}

/// The front end's whole failure posture: what goes wrong
/// ([`FaultSchedule`]) and the three degradation knobs — health-aware
/// failover routing, retry/backoff, proactive drain.
#[derive(Debug, Clone, PartialEq)]
pub struct ResilienceSpec {
    pub schedule: FaultSchedule,
    pub retry: RetryPolicy,
    /// Proactive pre-crash evacuation; `None` = reactive only.
    pub drain: Option<DrainSpec>,
    /// Health-aware routing: routers only see up replicas, and no
    /// request is ever offered to a down one. With failover *off* the
    /// router stays blind — a request routed onto a down replica fails
    /// on the spot (and JSQ happily black-holes traffic into a crashed
    /// replica's empty queue, which is exactly the pathology failover
    /// exists to prevent).
    pub failover: bool,
}

impl ResilienceSpec {
    /// Faults, failover and retries all off — the bitwise-free anchor.
    pub fn none() -> Self {
        ResilienceSpec {
            schedule: FaultSchedule::none(),
            retry: RetryPolicy::disabled(),
            drain: None,
            failover: true,
        }
    }

    pub fn with_schedule(mut self, schedule: FaultSchedule) -> Self {
        self.schedule = schedule;
        self
    }

    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    pub fn with_drain(mut self, drain: DrainSpec) -> Self {
        self.drain = Some(drain);
        self
    }

    pub fn with_failover(mut self, failover: bool) -> Self {
        self.failover = failover;
        self
    }

    pub fn describe(&self) -> String {
        format!(
            "{} | failover {} | {}{}",
            self.schedule.describe(),
            if self.failover { "on" } else { "off" },
            self.retry.describe(),
            if self.drain.is_some() { " + drain" } else { "" }
        )
    }
}

/// Degraded-mode truth surfaced in `FleetMetrics`: how many requests
/// the faults touched and what the fleet's availability was.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultStats {
    /// Scheduled fault events (all kinds).
    pub n_faults: usize,
    /// Crash events among them.
    pub n_crashes: usize,
    /// Request-failure events: in-flight work killed by a crash,
    /// in-flight handoffs to a crashed replica, offers with no healthy
    /// replica, offers routed onto a down replica with failover off.
    /// One request can fail several times (each retry may fail again).
    pub n_failed: usize,
    /// Re-offers scheduled by the retry policy (failures and sheds).
    pub n_retried: usize,
    /// Requests permanently lost: failed with the retry budget
    /// exhausted (a subset of `n_rejected`, like sheds).
    pub n_lost: usize,
    /// Mid-decode requests proactively evacuated ahead of crashes.
    pub n_drained: usize,
    /// Summed per-crash downtime, clamped to the makespan (s).
    pub downtime_s: f64,
    /// Mean effective recovery time per crash (s).
    pub mean_recovery_s: f64,
    /// `1 - downtime / (n_replicas * makespan)`: the fraction of
    /// replica-seconds the fleet was serving. 1.0 with no faults.
    pub availability: f64,
}

impl Default for FaultStats {
    fn default() -> Self {
        FaultStats {
            n_faults: 0,
            n_crashes: 0,
            n_failed: 0,
            n_retried: 0,
            n_lost: 0,
            n_drained: 0,
            downtime_s: 0.0,
            mean_recovery_s: 0.0,
            availability: 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_kind_labels() {
        assert_eq!(FaultKind::Crash { recovery_s: 1.0 }.label(), "crash");
        assert_eq!(
            FaultKind::Straggler { duration_s: 1.0, slowdown: 2.0 }.label(),
            "straggler"
        );
        assert_eq!(
            FaultKind::LinkDegrade { duration_s: 1.0, factor: 2.0 }.label(),
            "link"
        );
    }

    #[test]
    fn backoff_is_capped_exponential() {
        let r = RetryPolicy::capped(5, 0.1, 0.5);
        assert!(r.enabled());
        assert!((r.delay_s(1) - 0.1).abs() < 1e-12);
        assert!((r.delay_s(2) - 0.2).abs() < 1e-12);
        assert!((r.delay_s(3) - 0.4).abs() < 1e-12);
        assert!((r.delay_s(4) - 0.5).abs() < 1e-12, "cap must bind");
        assert!((r.delay_s(40) - 0.5).abs() < 1e-12);
        assert!(!RetryPolicy::disabled().enabled());
        assert_eq!(RetryPolicy::disabled().delay_s(1), 0.0);
    }

    #[test]
    fn seeded_schedules_are_deterministic_and_bounded() {
        let a = FaultSchedule::seeded(4, 100.0, 2, 3, 7);
        let b = FaultSchedule::seeded(4, 100.0, 2, 3, 7);
        assert_eq!(a, b, "same seed must give the same schedule");
        let c = FaultSchedule::seeded(4, 100.0, 2, 3, 8);
        assert_ne!(a, c, "different seeds must differ");
        assert_eq!(a.faults.len(), 5);
        for f in &a.faults {
            assert!(f.replica < 4);
            assert!(f.t_s >= 0.0 && f.t_s <= 100.0);
            match f.kind {
                FaultKind::Crash { recovery_s } => {
                    assert!(recovery_s >= 10.0 - 1e-9 && recovery_s <= 30.0 + 1e-9)
                }
                FaultKind::Straggler { duration_s, slowdown } => {
                    assert!(duration_s > 0.0);
                    assert!((2.0..=4.0).contains(&slowdown));
                }
                FaultKind::LinkDegrade { .. } => panic!("generator emits no link faults"),
            }
        }
        assert!(a.describe().contains("2 crash"));
        assert!(FaultSchedule::none().is_empty());
    }

    #[test]
    fn builders_clamp_pathological_knobs() {
        let s = FaultSchedule::none()
            .crash(0, -5.0, -1.0)
            .straggler(1, 2.0, 3.0, 0.25)
            .link_degrade(4.0, 1.0, 0.1);
        assert_eq!(s.faults[0].t_s, 0.0);
        assert_eq!(s.faults[0].kind, FaultKind::Crash { recovery_s: 0.0 });
        assert_eq!(
            s.faults[1].kind,
            FaultKind::Straggler { duration_s: 3.0, slowdown: 1.0 }
        );
        assert_eq!(
            s.faults[2].kind,
            FaultKind::LinkDegrade { duration_s: 1.0, factor: 1.0 }
        );
        let d = DrainSpec::new(-1.0, -2.0, 0);
        assert_eq!((d.lead_s, d.handoff_s_per_token, d.max_requests), (0.0, 0.0, 1));
    }

    #[test]
    fn resilience_spec_describes_its_posture() {
        let none = ResilienceSpec::none();
        assert!(none.schedule.is_empty());
        assert!(!none.retry.enabled());
        assert!(none.failover);
        let full = ResilienceSpec::none()
            .with_schedule(FaultSchedule::none().crash(0, 1.0, 2.0))
            .with_retry(RetryPolicy::capped(3, 0.05, 0.4))
            .with_drain(DrainSpec::new(0.5, 1e-7, 8));
        let d = full.describe();
        assert!(d.contains("1 crash"), "{d}");
        assert!(d.contains("retry x2"), "{d}");
        assert!(d.contains("drain"), "{d}");
        let stats = FaultStats::default();
        assert_eq!(stats.availability, 1.0);
        assert_eq!(stats.n_lost, 0);
    }
}

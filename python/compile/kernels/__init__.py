from .layout_gram import layout_gram, layout_gram_diag
from .rbf_gram import rbf_gram

__all__ = ["layout_gram", "layout_gram_diag", "rbf_gram"]

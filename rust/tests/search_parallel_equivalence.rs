//! Bitwise-equivalence properties for the parallel search outer loop
//! and the shared cross-run cost cache (PR 9).
//!
//! The search-layer parallelization (cell-parallel study grids with
//! index-ordered row assembly, candidate-parallel `search_kv` /
//! `search_resilience` / `compass_dse_fleet`) and the process-global
//! [`CostCache`] must not move a single bit anywhere: every study row,
//! run record, DSE winner, BO history and traced-cell byte stream is
//! compared between
//!
//! * one outer thread and many (`COMPASS_THREADS`, read by
//!   `sim::profile::outer_threads` at each grid launch);
//! * shared cache on and off (`COMPASS_SHARED_CACHE=0` — sharing is
//!   bitwise-sound because `BatchCoster::cost` is a pure function of
//!   the fingerprint + quantized key, and `Searched` GA seeds derive
//!   from the key alone);
//! * isolated costers and costers racing on one shared cache.
//!
//! Both env vars are process-global, so every mutation here is
//! serialized behind one static mutex and restored afterwards.

use std::sync::{Arc, Mutex};

use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::bo::{Gp, NativeGp};
use compass::dse::{self, DseConfig, FleetSpace, ResilienceSpace};
use compass::experiments as exp;
use compass::ga::GaConfig;
use compass::sim::{
    self, BatchCoster, CostCache, FaultSchedule, FleetConfig, Frontend, IterCost, KvDtype,
    KvSpec, MappingPolicy, RouterPolicy, SimConfig, SloSpec,
};
use compass::workload::serving::ServingStrategy;
use compass::workload::trace::TraceSpec;
use compass::workload::{ModelSpec, Request};

/// Serializes `COMPASS_THREADS` / `COMPASS_SHARED_CACHE` mutation
/// across the whole test binary.
static ENV_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with the outer-loop thread count pinned to `threads` and
/// cross-coster sharing forced on or off, restoring the previous
/// environment afterwards (a poisoned guard is fine: the next test
/// re-acquires the lock before reading).
fn with_env<T>(threads: usize, shared_cache: bool, f: impl FnOnce() -> T) -> T {
    let _g = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let old_threads = std::env::var("COMPASS_THREADS").ok();
    let old_shared = std::env::var("COMPASS_SHARED_CACHE").ok();
    std::env::set_var("COMPASS_THREADS", threads.to_string());
    std::env::set_var("COMPASS_SHARED_CACHE", if shared_cache { "1" } else { "0" });
    let out = f();
    match old_threads {
        Some(v) => std::env::set_var("COMPASS_THREADS", v),
        None => std::env::remove_var("COMPASS_THREADS"),
    }
    match old_shared {
        Some(v) => std::env::set_var("COMPASS_SHARED_CACHE", v),
        None => std::env::remove_var("COMPASS_SHARED_CACHE"),
    }
    out
}

fn with_threads<T>(n: usize, f: impl FnOnce() -> T) -> T {
    with_env(n, true, f)
}

fn tiny_hw() -> HwConfig {
    HwConfig::homogeneous(
        2,
        2,
        ChipletClass::S,
        Dataflow::WeightStationary,
        32.0,
        16.0,
    )
}

fn assert_serving_bitwise(a: &sim::ServingMetrics, b: &sim::ServingMetrics, ctx: &str) {
    assert_eq!(a.n_arrived, b.n_arrived, "{ctx}: arrived");
    assert_eq!(a.n_completed, b.n_completed, "{ctx}: completed");
    assert_eq!(a.n_rejected, b.n_rejected, "{ctx}: rejected");
    assert_eq!(a.n_preemptions, b.n_preemptions, "{ctx}: preemptions");
    assert_eq!(a.n_iterations, b.n_iterations, "{ctx}: iterations");
    assert_eq!(a.gen_tokens, b.gen_tokens, "{ctx}: gen tokens");
    assert_eq!(a.distinct_shapes, b.distinct_shapes, "{ctx}: shapes");
    assert_eq!(a.max_queue_depth, b.max_queue_depth, "{ctx}: max queue");
    for (name, x, y) in [
        ("makespan", a.makespan_s, b.makespan_s),
        ("busy", a.busy_s, b.busy_s),
        ("energy", a.energy_pj, b.energy_pj),
        ("ttft p99", a.ttft.p99, b.ttft.p99),
        ("tpot p99", a.tpot.p99, b.tpot.p99),
        ("ttft mean", a.ttft.mean, b.ttft.mean),
        ("tpot mean", a.tpot.mean, b.tpot.mean),
        ("slo goodput", a.slo_goodput_tps, b.slo_goodput_tps),
        ("occupancy", a.mean_batch_occupancy, b.mean_batch_occupancy),
        ("mean queue", a.mean_queue_depth, b.mean_queue_depth),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {name}");
    }
}

fn assert_fleet_bitwise(a: &sim::FleetMetrics, b: &sim::FleetMetrics, ctx: &str) {
    assert_eq!(a.per_replica.len(), b.per_replica.len(), "{ctx}: replicas");
    for (i, (x, y)) in a.per_replica.iter().zip(&b.per_replica).enumerate() {
        assert_serving_bitwise(x, y, &format!("{ctx}: replica {i}"));
    }
    assert_eq!(a.n_arrived, b.n_arrived, "{ctx}: arrived");
    assert_eq!(a.n_completed, b.n_completed, "{ctx}: completed");
    assert_eq!(a.n_rejected, b.n_rejected, "{ctx}: rejected");
    assert_eq!(a.n_shed, b.n_shed, "{ctx}: shed");
    assert_eq!(a.n_rebalanced, b.n_rebalanced, "{ctx}: rebalanced");
    assert_eq!(a.faults.n_failed, b.faults.n_failed, "{ctx}: failed");
    assert_eq!(a.faults.n_lost, b.faults.n_lost, "{ctx}: lost");
    assert_eq!(a.faults.n_drained, b.faults.n_drained, "{ctx}: drained");
    for (name, x, y) in [
        ("makespan", a.makespan_s, b.makespan_s),
        ("energy", a.energy_pj, b.energy_pj),
        ("ttft p99", a.ttft.p99, b.ttft.p99),
        ("tpot p99", a.tpot.p99, b.tpot.p99),
        ("slo goodput", a.slo_goodput_tps, b.slo_goodput_tps),
        ("imbalance", a.load_imbalance, b.load_imbalance),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {name}");
    }
}

fn records_json(records: &[sim::RunRecord]) -> String {
    records
        .iter()
        .map(|r| r.to_json())
        .collect::<Vec<_>>()
        .join("\n")
}

fn study_cfg() -> SimConfig {
    let mut cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
    cfg.max_batch = 8;
    cfg.eval_blocks = 1;
    cfg.ctx_bucket = 512;
    cfg
}

/// The sim study's rate x strategy grid, run serially and on four outer
/// threads: rows and `--record` JSONL must match bit for bit (ordered
/// assembly makes the parallel grid structurally identical to the
/// serial loop).
#[test]
fn sim_study_rows_and_records_bitwise_equal_across_threads() {
    let mut scene = exp::SimScene::new("sharegpt", 64.0, 5);
    scene.rates_rps = vec![2.0, 8.0];
    let hw = exp::sim_default_hw(64.0);
    let cfg = study_cfg();
    let serial = with_threads(1, || exp::sim_serving_study(&scene, &hw, &cfg, 3));
    let parallel = with_threads(4, || exp::sim_serving_study(&scene, &hw, &cfg, 3));
    assert_eq!(serial.len(), parallel.len());
    assert_eq!(serial.len(), 2 * ServingStrategy::ALL.len());
    for (i, (a, b)) in serial.iter().zip(&parallel).enumerate() {
        assert_eq!(a.strategy, b.strategy, "row {i}: strategy order");
        assert_eq!(a.rate_rps.to_bits(), b.rate_rps.to_bits(), "row {i}: rate");
        assert_serving_bitwise(&a.metrics, &b.metrics, &format!("row {i}"));
    }
    assert_eq!(
        records_json(&exp::sim_study_records(&serial)),
        records_json(&exp::sim_study_records(&parallel)),
        "run-record JSONL differs across thread counts"
    );
}

/// The KV layout x rate grid (quantized dtypes give every cell its own
/// cache fingerprint, so cells never cross-contaminate).
#[test]
fn kv_study_rows_bitwise_equal_across_threads() {
    let mut scene = exp::SimScene::new("sharegpt", 64.0, 6);
    scene.rates_rps = vec![3.0, 12.0];
    let hw = exp::sim_default_hw(64.0);
    let mut cfg = study_cfg();
    cfg.chunk_tokens = 64;
    cfg.kv_budget_tokens = 0;
    cfg.dram_gb = 2048.0 * ModelSpec::gpt3_7b().kv_bytes_per_token() as f64 / 1e9;
    let specs = exp::default_kv_specs(16, 64);
    let run = || exp::kv_paging_study(&scene, &hw, &cfg, &specs, 64, 3);
    let serial = with_threads(1, run);
    let parallel = with_threads(4, run);
    assert_eq!(serial.len(), parallel.len());
    assert_eq!(serial.len(), 2 * specs.len());
    for (i, (a, b)) in serial.iter().zip(&parallel).enumerate() {
        assert_eq!(a.kv.describe(), b.kv.describe(), "row {i}: layout order");
        assert_eq!(a.rate_rps.to_bits(), b.rate_rps.to_bits(), "row {i}: rate");
        assert_eq!(a.capacity_tokens, b.capacity_tokens, "row {i}: capacity");
        assert_serving_bitwise(&a.metrics, &b.metrics, &format!("row {i}"));
    }
    assert_eq!(
        records_json(&exp::kv_study_records(&serial)),
        records_json(&exp::kv_study_records(&parallel)),
    );
}

/// The fleet shape x rate grid.
#[test]
fn fleet_study_rows_bitwise_equal_across_threads() {
    let mut scene = exp::FleetScene::new("sharegpt", 64.0, 2, 6);
    scene.rates_rps = vec![4.0, 16.0];
    let hw = exp::sim_default_hw(scene.tops_per_replica());
    let cfg = study_cfg();
    let shapes = exp::default_fleet_shapes(scene.n_replicas, 1e-8);
    let run = || exp::fleet_study(&scene, &hw, &cfg, &shapes, 3);
    let serial = with_threads(1, run);
    let parallel = with_threads(4, run);
    assert_eq!(serial.len(), parallel.len());
    assert_eq!(serial.len(), 2 * shapes.len());
    for (i, (a, b)) in serial.iter().zip(&parallel).enumerate() {
        assert_eq!(a.fleet.describe(), b.fleet.describe(), "row {i}: shape");
        assert_eq!(a.rate_rps.to_bits(), b.rate_rps.to_bits(), "row {i}: rate");
        assert_fleet_bitwise(&a.metrics, &b.metrics, &format!("row {i}"));
    }
    assert_eq!(
        records_json(&exp::fleet_study_records(&serial)),
        records_json(&exp::fleet_study_records(&parallel)),
    );
}

/// The front-end cell ladder and the fault cell ladder (the two studies
/// whose outer rate loops stay serial and call the now-parallel
/// `*_study_stream` inside).
#[test]
fn frontend_and_fault_study_rows_bitwise_equal_across_threads() {
    let mut scene = exp::FleetScene::new("sharegpt", 64.0, 2, 6);
    scene.rates_rps = vec![4.0, 20.0];
    let model = ModelSpec::gpt3_7b();
    let hw = exp::sim_default_hw(scene.tops_per_replica());
    let cfg = study_cfg();

    let knobs = exp::FrontendKnobs::default();
    let run_fe = || exp::frontend_study_with_model(&scene, &model, &hw, &cfg, &knobs, 3);
    let serial = with_threads(1, run_fe);
    let parallel = with_threads(4, run_fe);
    assert_eq!(serial.len(), parallel.len());
    assert_eq!(serial.len(), 2 * 6, "2 rates x 6 cells");
    for (i, (a, b)) in serial.iter().zip(&parallel).enumerate() {
        assert_eq!(a.key, b.key, "frontend row {i}: cell order");
        assert_eq!(a.frontend_label, b.frontend_label, "frontend row {i}");
        assert_eq!(a.rate_rps.to_bits(), b.rate_rps.to_bits(), "frontend row {i}");
        assert_fleet_bitwise(&a.metrics, &b.metrics, &format!("frontend row {i}"));
    }
    assert_eq!(
        records_json(&exp::frontend_study_records(&serial)),
        records_json(&exp::frontend_study_records(&parallel)),
    );

    let fknobs = exp::FaultKnobs::default();
    let run_fault = || exp::fault_study_with_model(&scene, &model, &hw, &cfg, &fknobs, 3);
    let serial = with_threads(1, run_fault);
    let parallel = with_threads(4, run_fault);
    assert_eq!(serial.len(), parallel.len());
    assert_eq!(serial.len(), 2 * 6, "2 rates x 6 ladder cells");
    for (i, (a, b)) in serial.iter().zip(&parallel).enumerate() {
        assert_eq!(a.key, b.key, "fault row {i}: cell order");
        assert_eq!(a.n_replicas, b.n_replicas, "fault row {i}: replicas");
        assert_eq!(a.resilience_label, b.resilience_label, "fault row {i}");
        assert_fleet_bitwise(&a.metrics, &b.metrics, &format!("fault row {i}"));
    }
    assert_eq!(
        records_json(&exp::fault_study_records(&serial)),
        records_json(&exp::fault_study_records(&parallel)),
    );
}

fn tiny_sim_setup() -> (sim::RequestStream, ModelSpec, SimConfig) {
    let spec = TraceSpec {
        mean_in: 48.0,
        mean_out: 6.0,
        sigma_in: 0.4,
        sigma_out: 0.3,
        max_len: 2048,
        shared_prefix_tokens: 0,
    };
    let mut cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
    cfg.max_batch = 8;
    cfg.chunk_tokens = 32;
    cfg.kv_budget_tokens = 2048;
    cfg.ctx_bucket = 64;
    cfg.eval_blocks = 1;
    cfg.slo = SloSpec::new(1.0, 0.5);
    (
        sim::RequestStream::poisson(&spec, 50.0, 6, 13),
        ModelSpec::tiny(),
        cfg,
    )
}

/// The DSE entry points on 1 vs 4 outer threads: `search_serving` (GA
/// per distinct shape under the shared cache), the candidate-parallel
/// `search_kv`, `search_fleet`, and the grid-parallel
/// `search_resilience` — winners and every row, bitwise.
#[test]
fn search_entrypoints_bitwise_equal_across_threads() {
    let (stream, model, cfg) = tiny_sim_setup();
    let hw = tiny_hw();
    let ga = GaConfig::tiny();

    let a = with_threads(1, || dse::search_serving(&stream, &model, &hw, &ga, &cfg));
    let b = with_threads(4, || dse::search_serving(&stream, &model, &hw, &ga, &cfg));
    assert_serving_bitwise(&a, &b, "search_serving");

    let specs = [
        KvSpec::token_granular(),
        KvSpec::paged(16),
        KvSpec::paged(16).with_dtype(KvDtype::Int4),
    ];
    let (wa, rows_a) = with_threads(1, || dse::search_kv(&stream, &model, &hw, &cfg, &specs));
    let (wb, rows_b) = with_threads(4, || dse::search_kv(&stream, &model, &hw, &cfg, &specs));
    assert_eq!(wa.describe(), wb.describe(), "search_kv winner");
    assert_eq!(rows_a.len(), rows_b.len());
    for (i, (x, y)) in rows_a.iter().zip(&rows_b).enumerate() {
        assert_eq!(x.0.describe(), y.0.describe(), "search_kv row {i}: spec order");
        assert_serving_bitwise(&x.1, &y.1, &format!("search_kv row {i}"));
    }

    let fleet = FleetConfig::homogeneous(2, RouterPolicy::JoinShortestQueue);
    let fa = with_threads(1, || {
        dse::search_fleet(&stream, &model, &hw, &ga, &cfg, &fleet)
    });
    let fb = with_threads(4, || {
        dse::search_fleet(&stream, &model, &hw, &ga, &cfg, &fleet)
    });
    assert_fleet_bitwise(&fa, &fb, "search_fleet");

    let space = ResilienceSpace::new(2);
    let schedule = FaultSchedule::none().crash(0, 0.05, 0.2);
    let fe = Frontend::baseline();
    let (ba, rows_a) = with_threads(1, || {
        dse::search_resilience(&stream, &model, &hw, &cfg, &fe, &space, &schedule)
    });
    let (bb, rows_b) = with_threads(4, || {
        dse::search_resilience(&stream, &model, &hw, &cfg, &fe, &space, &schedule)
    });
    assert_eq!(ba.describe(), bb.describe(), "search_resilience winner");
    assert_eq!(rows_a.len(), rows_b.len());
    assert_eq!(
        rows_a.len(),
        space.extra_replicas.len() * space.retries.len() * space.drain_options.len(),
        "flattened grid covers the serial triple loop"
    );
    for (i, (x, y)) in rows_a.iter().zip(&rows_b).enumerate() {
        assert_eq!(x.0.describe(), y.0.describe(), "resilience row {i}: order");
        assert_fleet_bitwise(&x.1, &y.1, &format!("resilience row {i}"));
    }
}

/// `compass_dse_fleet` with the `GpFactory` signature: the candidate-
/// parallel run (8 threads -> outer width 2, fresh surrogate per
/// candidate) must reproduce the serial run's winner, BO history and
/// per-candidate objectives bit for bit — every `Gp::fit` retrains from
/// scratch, so a fresh surrogate sees exactly the data a reused one did.
#[test]
fn fleet_dse_bitwise_equal_across_threads() {
    let (stream, model, cfg) = tiny_sim_setup();
    let mut fspace = FleetSpace::new(64.0);
    fspace.replica_counts = vec![2];
    fspace.routers = vec![RouterPolicy::JoinShortestQueue];
    fspace.splits = vec![];
    fspace.hetero_splits = vec![(1, 1, 0.3)];
    fspace.shed_margins = vec![1.5];
    let dse_cfg = DseConfig::tiny();
    let make_gp = || -> Box<dyn Gp> { Box::new(NativeGp::new()) };
    let a = with_threads(1, || {
        dse::compass_dse_fleet(&stream, &model, &fspace, &dse_cfg, &cfg, &make_gp)
    });
    let b = with_threads(8, || {
        dse::compass_dse_fleet(&stream, &model, &fspace, &dse_cfg, &cfg, &make_gp)
    });
    assert_eq!(a.fleet.describe(), b.fleet.describe(), "winning shape");
    assert_eq!(
        a.shed_margin.map(f64::to_bits),
        b.shed_margin.map(f64::to_bits),
        "winning admission margin"
    );
    assert_eq!(format!("{:?}", a.hw), format!("{:?}", b.hw), "winning hw");
    assert_eq!(
        format!("{:?}", a.hws),
        format!("{:?}", b.hws),
        "per-replica hw vector"
    );
    assert_eq!(a.bo_history.len(), b.bo_history.len());
    for (i, (x, y)) in a.bo_history.iter().zip(&b.bo_history).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "bo history round {i}");
    }
    assert_eq!(a.per_shape.len(), b.per_shape.len());
    for (i, (x, y)) in a.per_shape.iter().zip(&b.per_shape).enumerate() {
        assert_eq!(x.0.describe(), y.0.describe(), "candidate {i}: order");
        assert_eq!(x.1.to_bits(), y.1.to_bits(), "candidate {i}: objective");
    }
    assert_fleet_bitwise(&a.metrics, &b.metrics, "winner metrics");
}

/// Traced representative cells re-run under `--trace-out`'s protocol:
/// the Chrome-trace JSON must be byte-identical at 1 and 4 threads —
/// the deterministic local coster counters (`coster.memo_hits`) enter
/// the trace, the nondeterministic shared-cache stats never do.
#[test]
fn traced_cells_byte_identical_across_threads() {
    let mut scene = exp::SimScene::new("sharegpt", 64.0, 5);
    scene.rates_rps = vec![2.0, 8.0];
    let hw = exp::sim_default_hw(64.0);
    let cfg = study_cfg();
    let run_sim = |threads: usize| {
        with_threads(threads, || {
            let (label, rate, sink) = exp::sim_study_traced_cell(&scene, &hw, &cfg, 3);
            let json = sink.lock().unwrap().chrome_trace_json();
            (label, rate, json)
        })
    };
    let (l1, r1, j1) = run_sim(1);
    let (l4, r4, j4) = run_sim(4);
    assert_eq!(l1, l4);
    assert_eq!(r1.to_bits(), r4.to_bits());
    assert!(!j1.is_empty() && j1.starts_with("{\"traceEvents\":["));
    assert_eq!(j1, j4, "sim-study trace bytes differ across threads");

    let mut fscene = exp::FleetScene::new("sharegpt", 64.0, 2, 6);
    fscene.rates_rps = vec![4.0, 20.0];
    let model = ModelSpec::gpt3_7b();
    let fhw = exp::sim_default_hw(fscene.tops_per_replica());
    let knobs = exp::FaultKnobs::default();
    let run_fault = |threads: usize| {
        with_threads(threads, || {
            let (label, rate, sink) =
                exp::fault_study_traced_cell(&fscene, &model, &fhw, &cfg, &knobs, 3);
            let json = sink.lock().unwrap().chrome_trace_json();
            (label, rate, json)
        })
    };
    let (l1, r1, j1) = run_fault(1);
    let (l4, r4, j4) = run_fault(4);
    assert_eq!(l1, l4);
    assert_eq!(r1.to_bits(), r4.to_bits());
    assert_eq!(j1, j4, "fault-study trace bytes differ across threads");
}

/// `COMPASS_SHARED_CACHE=0` (every coster back on its local memo only)
/// against the default shared-cache run, both on four threads: rows
/// bitwise-identical — the cache can only change *when* a shape is
/// simulated, never what the simulation returns.
#[test]
fn shared_cache_off_matches_on_bitwise() {
    let mut scene = exp::SimScene::new("sharegpt", 64.0, 5);
    scene.rates_rps = vec![2.0, 8.0];
    let hw = exp::sim_default_hw(64.0);
    let cfg = study_cfg();
    let on = with_env(4, true, || exp::sim_serving_study(&scene, &hw, &cfg, 3));
    let off = with_env(4, false, || exp::sim_serving_study(&scene, &hw, &cfg, 3));
    assert_eq!(on.len(), off.len());
    for (i, (a, b)) in on.iter().zip(&off).enumerate() {
        assert_eq!(a.strategy, b.strategy, "row {i}");
        assert_serving_bitwise(&a.metrics, &b.metrics, &format!("cache on/off row {i}"));
    }

    let (stream, model, tcfg) = tiny_sim_setup();
    let thw = tiny_hw();
    let space = ResilienceSpace::new(2);
    let schedule = FaultSchedule::none().crash(0, 0.05, 0.2);
    let fe = Frontend::baseline();
    let (won, rows_on) = with_env(4, true, || {
        dse::search_resilience(&stream, &model, &thw, &tcfg, &fe, &space, &schedule)
    });
    let (woff, rows_off) = with_env(4, false, || {
        dse::search_resilience(&stream, &model, &thw, &tcfg, &fe, &space, &schedule)
    });
    assert_eq!(won.describe(), woff.describe(), "winner flips with cache");
    for (i, (x, y)) in rows_on.iter().zip(&rows_off).enumerate() {
        assert_fleet_bitwise(&x.1, &y.1, &format!("cache on/off resilience row {i}"));
    }
}

/// Eight costers hammering one fresh [`CostCache`] with overlapping
/// batches, in worker-skewed order, under the `Searched` policy (GA
/// seeds derive from the quantized key, never from lookup order): every
/// worker's every cost must equal the isolated no-cache reference bit
/// for bit, each coster's explicit counters must add up, and the global
/// counters must balance the per-coster ones.
#[test]
fn concurrent_shared_cache_is_bitwise_deterministic() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let policy = MappingPolicy::Searched(GaConfig::tiny());
    let cache = Arc::new(CostCache::new());
    // 12 batches; prefill lengths collide after ctx_bucket=32
    // quantization, so workers race on genuinely shared keys
    let batches: Vec<Vec<Request>> = (0..12)
        .map(|i| {
            let mut b = vec![Request::Prefill {
                len: 16 * (i as u64 % 4 + 1),
                past: 0,
            }];
            for j in 0..(i % 3 + 1) {
                b.push(Request::Decode {
                    ctx: 32 * (j as u64 + 1) + (i as u64 % 2),
                });
            }
            b
        })
        .collect();
    let expect: Vec<IterCost> = {
        let mut reference =
            BatchCoster::with_cache(&model, &hw, policy, 1, 32, KvDtype::Fp16, None);
        batches.iter().map(|b| reference.cost(b)).collect()
    };
    let n = batches.len();
    let batches_ref = &batches;
    let (model_ref, hw_ref, cache_ref) = (&model, &hw, &cache);
    let per_worker: Vec<(Vec<IterCost>, usize, usize)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|w| {
                s.spawn(move || {
                    let mut c = BatchCoster::with_cache(
                        model_ref,
                        hw_ref,
                        policy,
                        1,
                        32,
                        KvDtype::Fp16,
                        Some(cache_ref.clone()),
                    );
                    // rotated visit order; every batch costed twice so
                    // local-memo hits and shared hits both occur
                    let mut out = vec![None; n];
                    for pass in 0..2 {
                        for k in 0..n {
                            let i = (k + w * (pass + 1)) % n;
                            out[i] = Some(c.cost(&batches_ref[i]));
                        }
                    }
                    assert_eq!(
                        c.lookups(),
                        c.hits() + c.shared_hits() + c.computed(),
                        "worker {w}: counter invariant"
                    );
                    (
                        out.into_iter().map(|c| c.unwrap()).collect::<Vec<_>>(),
                        c.shared_hits(),
                        c.computed(),
                    )
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut probes = 0usize;
    for (w, (costs, shared_hits, computed)) in per_worker.iter().enumerate() {
        probes += shared_hits + computed;
        for (i, (got, want)) in costs.iter().zip(&expect).enumerate() {
            assert_eq!(
                got.latency_cycles.to_bits(),
                want.latency_cycles.to_bits(),
                "worker {w} batch {i}: latency"
            );
            assert_eq!(
                got.energy_pj.to_bits(),
                want.energy_pj.to_bits(),
                "worker {w} batch {i}: energy"
            );
            assert_eq!(got.macs, want.macs, "worker {w} batch {i}: macs");
        }
    }
    let stats = cache.stats();
    assert_eq!(stats.configs, 1, "one fingerprint, one shard");
    assert_eq!(
        stats.hits + stats.misses,
        probes,
        "global counters balance the per-coster ones"
    );
    assert!(stats.entries >= 1 && stats.entries <= stats.misses);
    assert_eq!(
        stats.ga_searches, stats.misses,
        "every miss under Searched runs a GA"
    );
    assert_eq!(
        stats.ga_avoided, stats.hits,
        "every shared hit under Searched avoids a GA"
    );
}

//! Engine microbenchmarks (the EXPERIMENTS.md #Perf hot paths):
//! per-layer kernel cost, Algorithm-2 access analysis, timeline
//! simulation, batched generation evaluation, and full GA mapping
//! searches — serial closure vs the parallel, allocation-free
//! evaluation engine, with the speedups printed at the end.
use compass::arch::{Chiplet, ChipletClass, Dataflow, HwConfig};
use compass::cost::engine::{BatchEvaluator, EvalScratch, MappingEvaluator};
use compass::cost::{access, dataflow::layer_cost, Evaluator};
use compass::ga::{self, ops, GaConfig};
use compass::mapping::{presets, Mapping};
use compass::util::{Bench, Rng};
use compass::workload::{build_workload, LayerKind, ModelSpec, Request, WorkloadParams};

fn main() {
    let chip = Chiplet { class: ChipletClass::M, dataflow: Dataflow::WeightStationary };
    let gemm = LayerKind::Gemm { m: 4096, k: 4096, n: 16384 };
    Bench::new("layer_cost/gemm-4kx4kx16k").run(|| layer_cost(&gemm, 0, chip, true));
    let att = LayerKind::Attention {
        heads: 32,
        head_dim: 128,
        reqs: (0..128).map(|i| (1u64, 256 + 8 * i as u64)).collect(),
    };
    Bench::new("layer_cost/attention-128req").run(|| layer_cost(&att, 0, chip, false));

    let model = ModelSpec::gpt3_7b();
    let w = build_workload(
        &model,
        &vec![Request::decode(512); 128],
        &WorkloadParams { micro_batch_size: 64, tensor_parallel: 8, eval_blocks: 2 },
    );
    let hw = HwConfig::homogeneous(2, 4, ChipletClass::M, Dataflow::WeightStationary, 32.0, 16.0);
    let m = presets::pipeline_parallel(w.num_micro_batches(), w.layers_per_mb, 8);
    Bench::new("access_analysis/decode-128").run(|| access::analyze(&w, &m));
    let ev = Evaluator::new();
    let t_eval_oneshot = Bench::new("eval_batch/decode-128").run(|| ev.eval_batch(&w, &hw, &m));
    // prepared + scratch-reusing hot path (what every search iteration pays)
    let mev = MappingEvaluator::new(&w, &hw);
    let mut scratch = EvalScratch::default();
    let t_eval_prepared =
        Bench::new("eval_batch/decode-128-prepared").run(|| mev.simulate(&m, &mut scratch));
    Bench::new("workload_build/decode-128").run(|| {
        build_workload(
            &model,
            &vec![Request::decode(512); 128],
            &WorkloadParams { micro_batch_size: 64, tensor_parallel: 8, eval_blocks: 2 },
        )
    });

    // one GA generation's worth of distinct mappings, serial closure vs
    // the batch engine (fresh evaluator per call so the fitness memo
    // cannot serve repeats across bench iterations)
    let mut rng = Rng::seed_from_u64(1);
    let gen_maps: Vec<Mapping> = (0..24)
        .map(|_| ops::random_mapping(w.num_micro_batches(), w.layers_per_mb, 8, &mut rng))
        .collect();
    let t_gen_serial = Bench::new("eval_batch/gen24-serial").run(|| {
        gen_maps
            .iter()
            .map(|mm| {
                let r = ev.eval_batch(&w, &hw, mm);
                r.latency_cycles * r.energy_pj
            })
            .sum::<f64>()
    });
    let mut fits = Vec::new();
    let t_gen_parallel = Bench::new("eval_batch/gen24-parallel").run(|| {
        let fresh = MappingEvaluator::new(&w, &hw);
        fresh.eval_batch(&gen_maps, &mut fits);
        fits.iter().sum::<f64>()
    });

    // full GA search: the seed's serial FnMut-closure path vs the engine
    let cfg = GaConfig { population: 12, generations: 8, ..GaConfig::reduced() };
    let t_ga_serial = Bench::new("ga_search/pop12-gen8-serial").budget_ms(1200).run(|| {
        ga::search(w.num_micro_batches(), w.layers_per_mb, 8, &cfg, &|mm: &Mapping| {
            let r = ev.eval_batch(&w, &hw, mm);
            r.latency_cycles * r.energy_pj
        })
    });
    let t_ga_engine = Bench::new("ga_search/pop12-gen8").budget_ms(1200).run(|| {
        let fresh = MappingEvaluator::new(&w, &hw);
        ga::search(w.num_micro_batches(), w.layers_per_mb, 8, &cfg, &fresh)
    });

    println!(
        "speedup eval_batch/decode-128 (prepared vs one-shot): {:.2}x",
        t_eval_oneshot / t_eval_prepared
    );
    println!(
        "speedup eval_batch/gen24 (parallel engine vs serial): {:.2}x",
        t_gen_serial / t_gen_parallel
    );
    println!(
        "speedup ga_search/pop12-gen8 (engine vs serial closure): {:.2}x",
        t_ga_serial / t_ga_engine
    );
}

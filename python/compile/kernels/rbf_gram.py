"""L1 Pallas kernel: ARD-RBF Gram for system parameters (K_sys, Eq. 2).

    K[q, n] = exp(-0.5 * sum_d ((x[q,d] - y[n,d]) * inv_ls[d])^2)

Blocked over the (q, n) output grid; the squared distance is expanded as
|x|^2 - 2<x,y> + |y|^2 so the cross term is a single MXU dot per block.
Zero inverse-lengthscales disable padded feature dimensions.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rbf_kernel(x_ref, y_ref, ils_ref, o_ref):
    x = x_ref[...] * ils_ref[...][None, :]  # (bq, D)
    y = y_ref[...] * ils_ref[...][None, :]  # (bn, D)
    d2 = (
        jnp.sum(x * x, axis=1)[:, None]
        - 2.0 * (x @ y.T)
        + jnp.sum(y * y, axis=1)[None, :]
    )
    o_ref[...] = jnp.exp(-0.5 * jnp.maximum(d2, 0.0))


def rbf_gram(x, y, inv_ls, block_q=None, block_n=None):
    """Pallas ARD-RBF Gram. x: (Q,D), y: (N,D), inv_ls: (D,) -> (Q,N)."""
    q, d = x.shape
    n = y.shape[0]
    bq = min(block_q or q, q)
    bn = min(block_n or n, n)
    assert q % bq == 0 and n % bn == 0
    return pl.pallas_call(
        _rbf_kernel,
        grid=(q // bq, n // bn),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, n), x.dtype),
        interpret=True,
    )(x, y, inv_ls)

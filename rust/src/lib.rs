//! # Compass (reproduction)
//!
//! Co-exploration of mapping and hardware for heterogeneous multi-chiplet
//! accelerators targeting LLM inference service workloads.
//!
//! Layer map (see DESIGN.md):
//! * [`workload`] — LLM models, sequence-length traces, serving strategies,
//!   and the 2-D computation execution graph;
//! * [`mapping`]  — the paper's mapping encoding + Algorithm-1 presets;
//! * [`arch`]     — the multi-chiplet hardware template (Table IV space);
//! * [`cost`]     — the evaluation engine (intra-chiplet dataflow model,
//!   Algorithm-2 access analysis, timeline, monetary cost, and the
//!   batched multi-threaded search evaluator in [`cost::engine`]);
//! * [`ga`]       — genetic-algorithm mapping generation engine;
//! * [`bo`]       — Bayesian-optimization hardware sampling engine (GP
//!   surrogate executed via PJRT artifacts, two-tier SA acquisition);
//! * [`baselines`]— Gemini-, MOHaM-, SCAR-style and random baselines;
//! * [`runtime`]  — PJRT artifact loading/execution (`xla` crate, behind
//!   the non-default `xla` feature; a stub otherwise);
//! * [`dse`]      — the top-level co-exploration driver;
//! * [`sim`]      — discrete-event continuous-batching serving simulator
//!   (timed request streams, KV-budgeted scheduler, SLO metrics);
//! * [`report`]   — table/figure writers mirroring the paper.

pub mod arch;
pub mod baselines;
pub mod bo;
pub mod cost;
pub mod dse;
pub mod experiments;
pub mod ga;
pub mod log;
pub mod mapping;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;

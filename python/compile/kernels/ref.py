"""Pure-jnp oracles for the Pallas kernels (correctness references).

These implement Eq. 2-4 of the paper directly with jnp primitives and are
the ground truth for pytest/hypothesis sweeps in python/tests/.
"""

import jax.numpy as jnp


def layout_gram_ref(a, b, w, sigma2=1.0):
    """Chiplet-layout kernel of Eq. 3.

    K[q, n] = sigma2 * sum_t  a[q, :, t]^T  W  b[n, :, t]

    a: (Q, S, T) one-hot layout grids (all-zero rows = empty slots)
    b: (N, S, T)
    w: (S, S) Manhattan-distance weight matrix (Eq. 4)
    """
    return sigma2 * jnp.einsum("qut,uv,nvt->qn", a, w, b)


def layout_gram_diag_ref(a, w, sigma2=1.0):
    """diag of layout_gram_ref(a, a, w): K[q] = sigma2 * sum_t a_t^T W a_t."""
    return sigma2 * jnp.einsum("qut,uv,qvt->q", a, w, a)


def rbf_gram_ref(x, y, inv_ls):
    """ARD-RBF kernel for system parameters (K_sys in Eq. 2).

    K[q, n] = exp(-0.5 * sum_d ((x[q,d]-y[n,d]) * inv_ls[d])^2)

    x: (Q, D), y: (N, D), inv_ls: (D,) inverse lengthscales
    (zero inverse-lengthscale disables a padded dimension).
    """
    xs = x * inv_ls[None, :]
    ys = y * inv_ls[None, :]
    d2 = (
        jnp.sum(xs * xs, axis=1)[:, None]
        - 2.0 * xs @ ys.T
        + jnp.sum(ys * ys, axis=1)[None, :]
    )
    return jnp.exp(-0.5 * jnp.maximum(d2, 0.0))


def shape_indicator_ref(sa, sb):
    """1 + I(z_shape == z_shape') term of Eq. 2.

    sa: (Q, 2) integer-valued (H, W) array dims as f32; sb: (N, 2).
    """
    eq = jnp.all(sa[:, None, :] == sb[None, :, :], axis=-1)
    return 1.0 + eq.astype(sa.dtype)


def composite_gram_ref(xsys, ysys, inv_ls, a, b, w, sa, sb, sigma2):
    """Full hardware-aware composite kernel of Eq. 2."""
    return (
        rbf_gram_ref(xsys, ysys, inv_ls)
        * shape_indicator_ref(sa, sb)
        * layout_gram_ref(a, b, w, sigma2)
    )


def manhattan_weights_ref(coords, lam):
    """Eq. 4 positional-similarity weights.

    coords: (S, 2) slot (x, y) coordinates; padded slots may use any value
    (their one-hot rows are zero so they never contribute).
    """
    d = jnp.abs(coords[:, None, :] - coords[None, :, :]).sum(-1)
    return jnp.exp(-d / lam)

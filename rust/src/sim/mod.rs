//! Discrete-event continuous-batching serving simulator (the repo's
//! time-domain layer).
//!
//! Static scenario evaluation (`workload::serving::Scenario`) captures
//! *which* batches a strategy composes; this module captures *when*: a
//! timed request stream ([`stream::RequestStream`]) is replayed through
//! an iteration-level scheduler ([`sched::simulate_serving`]) that
//! implements all three `ServingStrategy` policies dynamically, with an
//! admission queue, a KV-cache budget per `HwConfig` DRAM capacity, and
//! per-request lifecycle tracking. Each iteration's batch composition is
//! costed through the existing `PreparedWorkload`/`MappingEvaluator`
//! path behind a composition-keyed memo ([`coster::BatchCoster`]), and
//! the run aggregates into [`metrics::ServingMetrics`]: throughput,
//! TTFT/TPOT tails, SLO attainment and EDP-under-load — the
//! SLO-constrained goodput objective consumed by
//! `dse::compass_dse_serving`.

pub mod coster;
pub mod events;
pub mod faults;
pub mod fleet;
pub mod frontend;
pub mod kv;
pub mod metrics;
pub mod sched;
pub mod stream;
pub mod telemetry;

pub use coster::{BatchCoster, CacheStats, CostCache, IterCost, MappingPolicy};
pub use events::EventHeap;
pub use faults::{
    DrainSpec, FaultKind, FaultSchedule, FaultSpec, FaultStats, ResilienceSpec, RetryPolicy,
};
pub use fleet::{simulate_fleet, simulate_fleet_traced, FleetConfig, FleetMetrics, RouterPolicy};
pub use frontend::{
    estimate_ttft, router_for, simulate_fleet_faults, simulate_fleet_faults_traced,
    simulate_fleet_frontend, simulate_fleet_frontend_traced, AdmissionPolicy, Frontend, JsqRouter,
    KvAwareRouter, RebalanceSpec, ReplicaObs, RoundRobinRouter, Router,
};
pub use kv::{EvictionPolicy, KvCache, KvDtype, KvGauges, KvSpec};
pub use metrics::{IterRecord, LatencyStats, RequestOutcome, ServingMetrics, SloSpec};
pub use sched::{
    simulate_serving, simulate_serving_traced, ExtractedRequest, FailedRequest, FrontendCounters,
    ReplicaResult, Scheduler,
};
pub use stream::{RequestStream, TimedRequest};
pub use telemetry::{
    profile, BufferSink, EventKind, IterSpan, NullSink, RequestLane, RunRecord, SharedSink, Span,
    SpanCollector, SpanKind, TraceSink,
};

use crate::arch::constants::CLOCK_HZ;
use crate::arch::HwConfig;
use crate::workload::serving::ServingStrategy;
use crate::workload::trace::TraceSpec;
use crate::workload::{ModelSpec, Request};

/// Simulator knobs (scheduler policy + costing + SLO targets).
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    pub strategy: ServingStrategy,
    /// How each iteration's batch is mapped onto the chiplets.
    pub policy: MappingPolicy,
    /// Maximum co-resident (admitted) requests, also the batch-slot cap.
    pub max_batch: usize,
    /// Sarathi chunk budget: prefill tokens per mixed iteration.
    pub chunk_tokens: u64,
    /// KV-cache budget in tokens; 0 derives it from `dram_gb`.
    pub kv_budget_tokens: u64,
    /// Package DRAM capacity reserved for KV cache (GB) when
    /// `kv_budget_tokens` is 0.
    pub dram_gb: f64,
    /// Costing quantization: context lengths are rounded up to this
    /// bucket so repeated batch shapes hit the latency memo.
    pub ctx_bucket: u64,
    /// Transformer blocks instantiated explicitly per costing call.
    pub eval_blocks: usize,
    pub slo: SloSpec,
    /// Safety valve on scheduler iterations per run.
    pub max_iterations: usize,
    /// Occupancy-trace record cap (0 = keep every iteration): long runs
    /// downsample the stored `IterRecord`s by deterministic pairwise
    /// merging, bounding memory at ~`2 * trace_cap` records per replica
    /// while the aggregate metrics stay exact.
    pub trace_cap: usize,
    /// KV-cache layout: block size, dtype, shared-prefix length and
    /// eviction policy. The default ([`KvSpec::token_granular`]) is
    /// bitwise-equal to the pre-paging scalar token counters.
    pub kv: KvSpec,
}

impl SimConfig {
    pub fn new(strategy: ServingStrategy) -> Self {
        SimConfig {
            strategy,
            policy: MappingPolicy::Pipeline,
            max_batch: 64,
            // the paper's Fig. 9/10 chunk size
            chunk_tokens: 2048,
            kv_budget_tokens: 0,
            dram_gb: 64.0,
            ctx_bucket: 256,
            eval_blocks: 2,
            slo: SloSpec::new(1.0, 0.1),
            max_iterations: 1_000_000,
            trace_cap: 4096,
            kv: KvSpec::token_granular(),
        }
    }

    pub fn with_strategy(mut self, strategy: ServingStrategy) -> Self {
        self.strategy = strategy;
        self
    }

    pub fn with_policy(mut self, policy: MappingPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn with_kv(mut self, kv: KvSpec) -> Self {
        self.kv = kv;
        self
    }

    /// KV-cache budget in tokens for `model`. The `kv_budget_tokens`
    /// override is dtype-agnostic (an explicit token count); the
    /// DRAM-derived path scales with the cache dtype, so fp8/int4
    /// quantization raises the effective token capacity 2x/4x.
    pub fn kv_budget(&self, model: &ModelSpec) -> u64 {
        if self.kv_budget_tokens > 0 {
            return self.kv_budget_tokens;
        }
        let per_token = self.kv.dtype.bytes_per_token(model).max(1);
        ((self.dram_gb * 1e9) as u64 / per_token).max(2)
    }
}

/// Calibration probe: single-request prefill latency and one full
/// decode-iteration latency at the stream's mean lengths, plus the
/// KV-feasible concurrency. Used to set scale-free SLO targets and
/// arrival-rate sweeps that stay meaningful across models/hardware.
#[derive(Debug, Clone, Copy)]
pub struct SimProbe {
    pub t_prefill_s: f64,
    pub t_decode_iter_s: f64,
    /// KV-budget-limited concurrent requests (<= max_batch).
    pub concurrency: usize,
    pub mean_in: u64,
    pub mean_out: u64,
}

impl SimProbe {
    /// Steady-state service capacity estimate (requests/s): each request
    /// costs one prefill plus its share of `mean_out` decode iterations.
    pub fn capacity_rps(&self) -> f64 {
        let per_req = self.t_prefill_s
            + self.mean_out as f64 * self.t_decode_iter_s / self.concurrency.max(1) as f64;
        1.0 / per_req.max(1e-12)
    }

    /// SLO targets as multiples of the unloaded latencies.
    pub fn slo(&self, ttft_mult: f64, tpot_mult: f64) -> SloSpec {
        SloSpec::new(ttft_mult * self.t_prefill_s, tpot_mult * self.t_decode_iter_s)
    }

    /// Default under/at/over-load sweep: {0.4, 0.8, 1.3} x capacity.
    pub fn sweep_rates(&self) -> Vec<f64> {
        let mu = self.capacity_rps();
        vec![0.4 * mu, 0.8 * mu, 1.3 * mu]
    }
}

/// Run the calibration probe for `(model, hw, spec)` under `cfg`
/// (always costed with the pipeline preset so SLOs are policy-neutral).
pub fn probe(model: &ModelSpec, hw: &HwConfig, cfg: &SimConfig, spec: &TraceSpec) -> SimProbe {
    let mut coster = BatchCoster::new(
        model,
        hw,
        MappingPolicy::Pipeline,
        cfg.eval_blocks,
        cfg.ctx_bucket,
        cfg.kv.dtype,
    );
    // the shared system-prompt prefix is added to every sampled prompt
    // (TraceSpec::sample), so the calibration prompt must carry it too
    let mean_in = (spec.mean_in.round() as u64).max(1) + spec.shared_prefix_tokens;
    let mean_out = (spec.mean_out.round() as u64).max(1);
    let budget = cfg.kv_budget(model);
    let per_req = (mean_in + mean_out).max(1);
    let concurrency = ((budget / per_req) as usize).clamp(1, cfg.max_batch.max(1));
    let pre = coster.cost(&[Request::prefill(mean_in)]);
    let ctx = mean_in + mean_out / 2;
    let dec_batch = vec![Request::decode(ctx); concurrency];
    let dec = coster.cost(&dec_batch);
    SimProbe {
        t_prefill_s: pre.latency_cycles / CLOCK_HZ,
        t_decode_iter_s: dec.latency_cycles / CLOCK_HZ,
        concurrency,
        mean_in,
        mean_out,
    }
}

/// [`probe`] calibrated from an actual request stream: the mean input
/// and output lengths are measured from the stream's requests instead
/// of a `TraceSpec`. Used where only the stream is in scope — the
/// fleet DSE's SLO-shed admission, and trace-file replays
/// (`RequestStream::from_trace`).
pub fn probe_stream(
    model: &ModelSpec,
    hw: &HwConfig,
    cfg: &SimConfig,
    stream: &RequestStream,
) -> SimProbe {
    let n = stream.requests.len().max(1) as u64;
    let mean_in = (stream.requests.iter().map(|r| r.input_len).sum::<u64>() / n).max(1);
    let mean_out = (stream.requests.iter().map(|r| r.output_len).sum::<u64>() / n).max(1);
    let spec = TraceSpec {
        mean_in: mean_in as f64,
        mean_out: mean_out as f64,
        sigma_in: 0.0,
        sigma_out: 0.0,
        max_len: u64::MAX,
        shared_prefix_tokens: 0,
    };
    probe(model, hw, cfg, &spec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ChipletClass, Dataflow};

    #[test]
    fn probe_stream_matches_probe_at_the_stream_means() {
        let model = ModelSpec::tiny();
        let hw = HwConfig::homogeneous(
            2,
            2,
            ChipletClass::S,
            Dataflow::WeightStationary,
            32.0,
            16.0,
        );
        let cfg = SimConfig::new(ServingStrategy::Orca);
        let stream = RequestStream {
            name: "fixed".into(),
            requests: vec![
                TimedRequest { id: 0, arrival_s: 0.0, input_len: 100, output_len: 10 },
                TimedRequest { id: 1, arrival_s: 1.0, input_len: 60, output_len: 30 },
            ],
            rate_rps: 1.0,
            seed: 0,
        };
        let p = probe_stream(&model, &hw, &cfg, &stream);
        assert_eq!(p.mean_in, 80);
        assert_eq!(p.mean_out, 20);
        assert!(p.t_prefill_s > 0.0 && p.t_decode_iter_s > 0.0);
        assert!(p.capacity_rps() > 0.0);
    }

    #[test]
    fn kv_budget_derivation() {
        let model = ModelSpec::gpt3_13b();
        let mut cfg = SimConfig::new(ServingStrategy::Orca);
        cfg.dram_gb = 64.0;
        // 13B: 2 * 40 kv-heads * 128 * 2 B * 40 blocks = 819200 B/token
        assert_eq!(model.kv_bytes_per_token(), 819_200);
        let budget = cfg.kv_budget(&model);
        assert_eq!(budget, 64_000_000_000 / 819_200);
        // cache quantization scales the DRAM-derived token capacity
        cfg.kv = cfg.kv.with_dtype(KvDtype::Fp8);
        assert_eq!(cfg.kv_budget(&model), 2 * budget);
        cfg.kv = cfg.kv.with_dtype(KvDtype::Int4);
        assert_eq!(cfg.kv_budget(&model), 4 * budget);
        // the explicit token override is dtype-agnostic
        cfg.kv_budget_tokens = 1234;
        assert_eq!(cfg.kv_budget(&model), 1234);
    }

    #[test]
    fn probe_yields_positive_calibration() {
        let model = ModelSpec::tiny();
        let hw = HwConfig::homogeneous(
            2,
            2,
            ChipletClass::S,
            Dataflow::WeightStationary,
            32.0,
            16.0,
        );
        let cfg = SimConfig::new(ServingStrategy::Orca);
        let spec = TraceSpec {
            mean_in: 512.0,
            mean_out: 16.0,
            sigma_in: 0.4,
            sigma_out: 0.3,
            max_len: 8192,
            shared_prefix_tokens: 0,
        };
        let p = probe(&model, &hw, &cfg, &spec);
        assert!(p.t_prefill_s > 0.0 && p.t_decode_iter_s > 0.0);
        assert!(p.capacity_rps() > 0.0);
        assert!(p.concurrency >= 1);
        let rates = p.sweep_rates();
        assert_eq!(rates.len(), 3);
        assert!(rates[0] < rates[1] && rates[1] < rates[2]);
        let slo = p.slo(3.0, 4.0);
        assert!(slo.ttft_s > 0.0 && slo.tpot_s > 0.0);
        // the calibration prompt carries the shared prefix the sampler
        // adds to every request, so capacity can only go down
        let pp = probe(&model, &hw, &cfg, &spec.with_prefix(512));
        assert_eq!(pp.mean_in, p.mean_in + 512);
        assert!(pp.t_prefill_s >= p.t_prefill_s);
        assert!(pp.capacity_rps() <= p.capacity_rps());
    }
}

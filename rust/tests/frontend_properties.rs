//! Router-trait equivalence anchors and front-end control-plane
//! properties.
//!
//! The heart of this file is a *verbatim reimplementation* of the
//! pre-refactor fleet routers (PR 3's inline match arms in
//! `sim/fleet.rs`), driven through the public `Scheduler` API. Over
//! randomized streams, rates, strategies and KV budgets, each legacy
//! `RouterPolicy` variant must be bitwise-identical — per-replica
//! metrics *and* per-request timings — to the trait-based front end
//! (`simulate_fleet`, now a thin wrapper over
//! `simulate_fleet_frontend` + `Frontend::baseline`). On top of that:
//! shedding with an infinite margin and rebalancing with an infinite
//! threshold are the baseline bit for bit, and an engineered
//! imbalance scenario proves the rebalancer migrates mid-decode
//! requests over the block-granular KV handoff path.

use std::sync::{Arc, Mutex};

use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::sim::{
    self, AdmissionPolicy, BatchCoster, FleetConfig, Frontend, KvCache, MappingPolicy,
    RebalanceSpec, RequestOutcome, RequestStream, RouterPolicy, Scheduler, ServingMetrics,
    SimConfig, SloSpec, TimedRequest,
};
use compass::util::Rng;
use compass::workload::serving::ServingStrategy;
use compass::workload::trace::TraceSpec;
use compass::workload::ModelSpec;

fn tiny_hw() -> HwConfig {
    HwConfig::homogeneous(
        2,
        2,
        ChipletClass::S,
        Dataflow::WeightStationary,
        32.0,
        16.0,
    )
}

fn tiny_spec() -> TraceSpec {
    TraceSpec {
        mean_in: 48.0,
        mean_out: 8.0,
        sigma_in: 0.5,
        sigma_out: 0.4,
        max_len: 4096,
        shared_prefix_tokens: 0,
    }
}

fn cfg_for(strategy: ServingStrategy, kv_tokens: u64) -> SimConfig {
    let mut cfg = SimConfig::new(strategy);
    cfg.policy = MappingPolicy::Pipeline;
    cfg.max_batch = 6;
    cfg.chunk_tokens = 24;
    cfg.kv_budget_tokens = kv_tokens;
    cfg.ctx_bucket = 32;
    cfg.eval_blocks = 1;
    cfg.slo = SloSpec::new(0.5, 0.1);
    cfg.max_iterations = 500_000;
    cfg
}

// ---------------------------------------------------------------------
// Verbatim pre-refactor routers (PR 3's fleet.rs), public-API edition
// ---------------------------------------------------------------------

fn jsq_pick(reps: &[Scheduler]) -> usize {
    let mut best = 0usize;
    let mut best_backlog = u64::MAX;
    for (i, s) in reps.iter().enumerate() {
        let b = s.backlog_tokens();
        if b < best_backlog {
            best_backlog = b;
            best = i;
        }
    }
    best
}

type SharedCoster<'a> = Arc<Mutex<BatchCoster<'a>>>;

fn shared_coster<'a>(model: &'a ModelSpec, hw: &'a HwConfig, cfg: &SimConfig) -> SharedCoster<'a> {
    Arc::new(Mutex::new(BatchCoster::new(
        model,
        hw,
        cfg.policy,
        cfg.eval_blocks,
        cfg.ctx_bucket,
        cfg.kv.dtype,
    )))
}

/// The old `simulate_homogeneous`, line for line.
fn legacy_homogeneous(
    stream: &RequestStream,
    model: &ModelSpec,
    hw: &HwConfig,
    cfg: &SimConfig,
    fleet: &FleetConfig,
) -> (Vec<ServingMetrics>, Vec<RequestOutcome>) {
    let n_rep = fleet.n_replicas.max(1);
    let coster = shared_coster(model, hw, cfg);
    let mut reps: Vec<Scheduler> = (0..n_rep)
        .map(|_| Scheduler::with_coster(model, hw, cfg, coster.clone()))
        .collect();
    let mut rr_next = 0usize;
    for r in &stream.requests {
        for s in reps.iter_mut() {
            s.advance_to(r.arrival_s);
        }
        let k = match fleet.router {
            RouterPolicy::RoundRobin => {
                let k = rr_next % n_rep;
                rr_next += 1;
                k
            }
            _ => jsq_pick(&reps),
        };
        reps[k].inject(r.id, r.arrival_s, r.input_len, r.output_len);
    }
    for s in reps.iter_mut() {
        s.run_to_end();
    }
    let mut per_replica = Vec::with_capacity(n_rep);
    let mut outcomes: Vec<RequestOutcome> = Vec::with_capacity(stream.requests.len());
    for s in reps {
        let r = s.finish();
        outcomes.extend(r.outcomes.iter().map(|&(_, o)| o));
        per_replica.push(r.metrics);
    }
    (per_replica, outcomes)
}

struct LegacyMigration {
    t: f64,
    id: usize,
    ctx: u64,
    rest: u64,
}

/// The old `simulate_disaggregated`, line for line.
fn legacy_disaggregated(
    stream: &RequestStream,
    model: &ModelSpec,
    hw: &HwConfig,
    cfg: &SimConfig,
    fleet: &FleetConfig,
) -> (Vec<ServingMetrics>, Vec<RequestOutcome>) {
    let (n_pre, n_dec) = (fleet.n_prefill.max(1), fleet.n_decode.max(1));
    let coster = shared_coster(model, hw, cfg);
    let fit_probe = KvCache::new(cfg.kv, cfg.kv_budget(model).max(2));
    let mut pre: Vec<Scheduler> = (0..n_pre)
        .map(|_| Scheduler::with_coster(model, hw, cfg, coster.clone()))
        .collect();
    for r in &stream.requests {
        for s in pre.iter_mut() {
            s.advance_to(r.arrival_s);
        }
        let k = jsq_pick(&pre);
        let out = r.output_len.max(1);
        if !fit_probe.can_ever_fit(r.input_len.max(1), out) {
            pre[k].inject(r.id, r.arrival_s, r.input_len, out);
        } else {
            pre[k].inject(r.id, r.arrival_s, r.input_len, 1);
        }
    }
    for s in pre.iter_mut() {
        s.run_to_end();
    }
    let mut per_replica = Vec::with_capacity(n_pre + n_dec);
    let mut pre_outcomes: Vec<(usize, RequestOutcome)> = Vec::with_capacity(stream.requests.len());
    for s in pre {
        let r = s.finish();
        pre_outcomes.extend(r.outcomes);
        per_replica.push(r.metrics);
    }

    let out_len_of: std::collections::HashMap<usize, u64> = stream
        .requests
        .iter()
        .map(|r| (r.id, r.output_len.max(1)))
        .collect();
    let mut migs: Vec<LegacyMigration> = Vec::new();
    for &(id, o) in &pre_outcomes {
        let (Some(finish), false) = (o.finish_s, o.rejected) else {
            continue;
        };
        let rest = out_len_of.get(&id).copied().unwrap_or(1).saturating_sub(1);
        if rest == 0 {
            continue;
        }
        let ctx = o.input_len + 1;
        let link_tokens = cfg.kv.block_round(ctx);
        migs.push(LegacyMigration {
            t: finish + link_tokens as f64 * fleet.handoff_s_per_token.max(0.0),
            id,
            ctx,
            rest,
        });
    }
    migs.sort_by(|a, b| a.t.total_cmp(&b.t).then(a.id.cmp(&b.id)));

    let mut dec: Vec<Scheduler> = (0..n_dec)
        .map(|_| Scheduler::with_coster(model, hw, cfg, coster.clone()))
        .collect();
    for m in &migs {
        for s in dec.iter_mut() {
            s.advance_to(m.t);
        }
        let k = jsq_pick(&dec);
        dec[k].inject_migrated(m.id, m.t, m.ctx, m.rest);
    }
    for s in dec.iter_mut() {
        s.run_to_end();
    }
    let mut dec_outcomes: Vec<(usize, RequestOutcome)> = Vec::with_capacity(migs.len());
    for s in dec {
        let r = s.finish();
        dec_outcomes.extend(r.outcomes);
        per_replica.push(r.metrics);
    }

    let dec_by_id: std::collections::HashMap<usize, RequestOutcome> =
        dec_outcomes.into_iter().collect();
    let outcomes: Vec<RequestOutcome> = pre_outcomes
        .iter()
        .map(|&(id, p)| {
            let out_len = out_len_of.get(&id).copied().unwrap_or(1);
            let mut o = RequestOutcome {
                arrival_s: p.arrival_s,
                input_len: p.input_len,
                output_len: out_len,
                first_token_s: p.first_token_s,
                finish_s: if out_len == 1 { p.finish_s } else { None },
                rejected: p.rejected,
            };
            if let Some(d) = dec_by_id.get(&id) {
                o.rejected = p.rejected || d.rejected;
                o.finish_s = d.finish_s;
            }
            o
        })
        .collect();
    (per_replica, outcomes)
}

fn legacy(
    stream: &RequestStream,
    model: &ModelSpec,
    hw: &HwConfig,
    cfg: &SimConfig,
    fleet: &FleetConfig,
) -> (Vec<ServingMetrics>, Vec<RequestOutcome>) {
    match fleet.router {
        RouterPolicy::PrefillDecode => legacy_disaggregated(stream, model, hw, cfg, fleet),
        _ => legacy_homogeneous(stream, model, hw, cfg, fleet),
    }
}

fn assert_bitwise_equal(
    m: &sim::FleetMetrics,
    per_replica: &[ServingMetrics],
    outcomes: &[RequestOutcome],
    ctx: &str,
) {
    assert_eq!(m.per_replica.len(), per_replica.len(), "{ctx}: replica count");
    for (i, (a, b)) in m.per_replica.iter().zip(per_replica).enumerate() {
        assert_eq!(
            a.makespan_s.to_bits(),
            b.makespan_s.to_bits(),
            "{ctx}: replica {i} makespan"
        );
        assert_eq!(
            a.energy_pj.to_bits(),
            b.energy_pj.to_bits(),
            "{ctx}: replica {i} energy"
        );
        assert_eq!(a.busy_s.to_bits(), b.busy_s.to_bits(), "{ctx}: replica {i} busy");
        assert_eq!(a.n_iterations, b.n_iterations, "{ctx}: replica {i} iterations");
        assert_eq!(a.n_preemptions, b.n_preemptions, "{ctx}: replica {i} preemptions");
        assert_eq!(a.n_arrived, b.n_arrived, "{ctx}: replica {i} arrivals");
    }
    assert_eq!(m.outcomes.len(), outcomes.len(), "{ctx}: outcome count");
    for (i, (a, b)) in m.outcomes.iter().zip(outcomes).enumerate() {
        assert_eq!(
            a.arrival_s.to_bits(),
            b.arrival_s.to_bits(),
            "{ctx}: outcome {i} arrival"
        );
        assert_eq!(a.input_len, b.input_len, "{ctx}: outcome {i} input");
        assert_eq!(a.output_len, b.output_len, "{ctx}: outcome {i} output");
        assert_eq!(
            a.first_token_s.map(f64::to_bits),
            b.first_token_s.map(f64::to_bits),
            "{ctx}: outcome {i} first token"
        );
        assert_eq!(
            a.finish_s.map(f64::to_bits),
            b.finish_s.map(f64::to_bits),
            "{ctx}: outcome {i} finish"
        );
        assert_eq!(a.rejected, b.rejected, "{ctx}: outcome {i} rejected");
    }
}

fn shapes() -> Vec<FleetConfig> {
    vec![
        FleetConfig::homogeneous(2, RouterPolicy::RoundRobin),
        FleetConfig::homogeneous(3, RouterPolicy::JoinShortestQueue),
        FleetConfig::disaggregated(1, 2, 1e-7),
    ]
}

/// Each legacy `RouterPolicy` variant is bitwise-identical —
/// `FleetMetrics` per-replica state and per-request timings — to its
/// trait impl, over randomized fleet scenarios.
#[test]
fn router_trait_matches_legacy_routers_bitwise() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let mut rng = Rng::seed_from_u64(0xF0E);
    let shapes = shapes();
    for trial in 0..9 {
        let fleet = &shapes[trial % shapes.len()];
        let strategy = ServingStrategy::ALL[trial % 3];
        let kv_tokens = *rng.choose(&[4096u64, 768, 200]);
        let rate_scale = 0.4 + rng.gen_f64() * 2.0;
        let n = 8 + rng.gen_index(8);
        let seed = rng.next_u64();
        let cfg = cfg_for(strategy, kv_tokens);
        let probe = sim::probe(&model, &hw, &cfg, &tiny_spec());
        let rate = rate_scale * fleet.total_replicas() as f64 * probe.capacity_rps();
        let stream = RequestStream::poisson(&tiny_spec(), rate, n, seed);
        let ctx = format!(
            "{} {strategy:?} kv={kv_tokens} scale={rate_scale:.2} n={n} seed={seed}",
            fleet.describe()
        );
        let m = sim::simulate_fleet(&stream, &model, &hw, &cfg, fleet);
        let (per, outs) = legacy(&stream, &model, &hw, &cfg, fleet);
        assert_bitwise_equal(&m, &per, &outs, &ctx);
    }
}

/// Shedding disabled (infinite margin) and rebalancing disabled
/// (infinite threshold) are today's admission, bit for bit — the
/// "off" switches genuinely run the baseline path.
#[test]
fn disabled_frontend_features_are_todays_admission_bitwise() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let cfg = cfg_for(ServingStrategy::ChunkedPrefill, 768);
    let probe = sim::probe(&model, &hw, &cfg, &tiny_spec());
    for fleet in shapes() {
        let rate = 1.3 * fleet.total_replicas() as f64 * probe.capacity_rps();
        let stream = RequestStream::poisson(&tiny_spec(), rate, 13, 77);
        let (per, outs) = legacy(&stream, &model, &hw, &cfg, &fleet);
        let hws = vec![hw.clone(); fleet.total_replicas()];
        let fe = Frontend {
            admission: AdmissionPolicy::SloShed {
                probe,
                margin: f64::INFINITY,
            },
            rebalance: Some(RebalanceSpec::new(f64::INFINITY, 1e-7)),
        };
        let m = sim::simulate_fleet_frontend(&stream, &model, &hws, &cfg, &fleet, &fe);
        assert_eq!(m.n_shed, 0, "{}", fleet.describe());
        assert_eq!(m.n_rebalanced, 0, "{}", fleet.describe());
        assert_bitwise_equal(&m, &per, &outs, &fleet.describe());
    }
}

/// An engineered busy-time imbalance (round-robin pins a long request
/// plus a newcomer on replica 0 while replica 1 drains) makes the
/// rebalancer migrate a mid-decode request over the KV handoff path;
/// the run conserves, stitches origin timings, and stays
/// deterministic.
#[test]
fn rebalancer_migrates_mid_decode_and_conserves() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let mut cfg = cfg_for(ServingStrategy::Orca, 4096);
    cfg.max_batch = 4;
    let spec = TraceSpec {
        mean_in: 60.0,
        mean_out: 40.0,
        sigma_in: 0.1,
        sigma_out: 0.1,
        max_len: 4096,
        shared_prefix_tokens: 0,
    };
    let probe = sim::probe(&model, &hw, &cfg, &spec);
    let t2 = probe.t_prefill_s + 5.0 * probe.t_decode_iter_s;
    let mk = |id: usize, arrival_s: f64, input_len: u64, output_len: u64| TimedRequest {
        id,
        arrival_s,
        input_len,
        output_len,
    };
    let stream = RequestStream {
        name: "engineered-imbalance".into(),
        requests: vec![mk(0, 0.0, 60, 40), mk(1, 1e-6, 20, 2), mk(2, t2, 20, 2)],
        rate_rps: 1.0,
        seed: 0,
    };
    let fleet = FleetConfig::homogeneous(2, RouterPolicy::RoundRobin);
    let hws = vec![hw.clone(); 2];
    let fe = Frontend {
        admission: AdmissionPolicy::ArrivalReject,
        rebalance: Some(RebalanceSpec::new(0.05, 1e-7)),
    };
    let m = sim::simulate_fleet_frontend(&stream, &model, &hws, &cfg, &fleet, &fe);
    assert!(
        m.n_rebalanced >= 1,
        "engineered imbalance must trigger at least one migration"
    );
    assert_eq!(m.n_completed, 3);
    assert_eq!(m.n_rejected, 0);
    assert!(
        m.kv_transfer_tokens > 0,
        "rebalancing must account its KV handoff traffic"
    );
    // the migrated request's stitched outcome keeps origin timings
    let o = m
        .outcomes
        .iter()
        .find(|o| o.output_len == 40)
        .expect("long request present");
    assert_eq!(o.arrival_s, 0.0, "origin arrival must survive migration");
    let (first, finish) = (o.first_token_s.unwrap(), o.finish_s.unwrap());
    assert!(finish > first, "finish {finish} <= first token {first}");
    // deterministic
    let b = sim::simulate_fleet_frontend(&stream, &model, &hws, &cfg, &fleet, &fe);
    assert_eq!(m.makespan_s.to_bits(), b.makespan_s.to_bits());
    assert_eq!(m.n_rebalanced, b.n_rebalanced);
    assert_eq!(m.kv_transfer_tokens, b.kv_transfer_tokens);
}

/// Rebalancing under randomized overload keeps fleet conservation and
/// never loses a request, whatever it decides to migrate.
#[test]
fn rebalanced_fleets_conserve_over_randomized_runs() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let mut rng = Rng::seed_from_u64(0xBA1);
    for trial in 0..6 {
        let strategy = ServingStrategy::ALL[trial % 3];
        let cfg = cfg_for(strategy, *rng.choose(&[4096u64, 512]));
        let probe = sim::probe(&model, &hw, &cfg, &tiny_spec());
        let n_rep = 2 + trial % 2;
        let router = if trial % 2 == 0 {
            RouterPolicy::RoundRobin
        } else {
            RouterPolicy::JoinShortestQueue
        };
        let fleet = FleetConfig::homogeneous(n_rep, router);
        let rate = (0.5 + rng.gen_f64() * 2.0) * n_rep as f64 * probe.capacity_rps();
        let stream =
            RequestStream::poisson(&tiny_spec(), rate, 10 + rng.gen_index(8), rng.next_u64());
        let hws = vec![hw.clone(); n_rep];
        let fe = Frontend {
            admission: AdmissionPolicy::ArrivalReject,
            rebalance: Some(RebalanceSpec::new(0.2, 1e-7)),
        };
        let m = sim::simulate_fleet_frontend(&stream, &model, &hws, &cfg, &fleet, &fe);
        assert_eq!(
            m.n_completed + m.n_rejected,
            m.n_arrived,
            "{} {strategy:?} rebalanced run lost a request",
            fleet.describe()
        );
        assert!(!m.truncated, "{} {strategy:?}", fleet.describe());
        assert_eq!(m.outcomes.len(), m.n_arrived);
    }
}

/// SLO-aware shedding under overload: conservation holds, the shed
/// rate is reported, and every shed request is also a rejection —
/// the shed-rate-vs-baseline comparison the metrics promise.
#[test]
fn shedding_reports_rate_and_stays_within_rejections() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let mut cfg = cfg_for(ServingStrategy::ChunkedPrefill, 2048);
    let probe = sim::probe(&model, &hw, &cfg, &tiny_spec());
    cfg.slo = probe.slo(3.0, 4.0);
    for fleet in shapes() {
        let rate = 2.5 * fleet.total_replicas() as f64 * probe.capacity_rps();
        let stream = RequestStream::poisson(&tiny_spec(), rate, 16, 5);
        let hws = vec![hw.clone(); fleet.total_replicas()];
        let base = sim::simulate_fleet(&stream, &model, &hw, &cfg, &fleet);
        let fe = Frontend::with_shedding(probe, 1.0);
        let m = sim::simulate_fleet_frontend(&stream, &model, &hws, &cfg, &fleet, &fe);
        assert_eq!(m.n_completed + m.n_rejected, m.n_arrived, "{}", fleet.describe());
        assert!(m.n_shed <= m.n_rejected, "{}", fleet.describe());
        assert_eq!(base.n_shed, 0, "baseline must not shed");
        assert!(
            (m.shed_rate - m.n_shed as f64 / m.n_arrived as f64).abs() < 1e-12,
            "{}",
            fleet.describe()
        );
    }
}

//! Pluggable fleet front-end control plane.
//!
//! PR 3 hard-wired the fleet's three routing policies as inline match
//! arms and rejected only never-fitting requests; this module turns the
//! front end into policy objects the DSE can co-search:
//!
//! * [`Router`] — per-request replica selection from read-only
//!   per-replica observations ([`ReplicaObs`]: queue depth, backlog
//!   tokens, KV headroom, busy time, phase mix). The legacy
//!   `RouterPolicy` variants are impls ([`RoundRobinRouter`],
//!   [`JsqRouter`]) that are bitwise-equal to the old match arms
//!   (property-tested in `rust/tests/frontend_properties.rs` against a
//!   verbatim reimplementation of the pre-refactor routers).
//! * [`AdmissionPolicy`] — front-door load shedding. The baseline keeps
//!   today's arrival-time rejection (requests that can never fit the KV
//!   budget, rejected by the scheduler); SLO-aware shedding
//!   additionally drops requests whose estimated TTFT under the routed
//!   replica's current backlog — calibrated by a [`SimProbe`] — already
//!   exceeds the SLO. Shed counts and the shed rate are reported in
//!   `FleetMetrics` next to the baseline's rejections.
//! * [`RebalanceSpec`] — decode-pool rebalancing: under busy-time
//!   imbalance the front end extracts the youngest mid-decode request
//!   from the busiest replica and migrates it to the least busy one,
//!   reusing the block-granular KV handoff path of the disaggregated
//!   router (`Scheduler::inject_migrated`), with the link delay charged
//!   on the block-rounded context.
//!
//! With the baseline front end ([`Frontend::baseline`]) and identical
//! per-replica hardware, every path here is bitwise-identical to the
//! pre-refactor `simulate_fleet`. Heterogeneous fleets pass one
//! `HwConfig` per replica (prefill pool first for disaggregated
//! shapes); replicas with equal hardware share one cost memo, exactly
//! as before.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::arch::HwConfig;
use crate::workload::ModelSpec;

use super::coster::BatchCoster;
use super::events::EventHeap;
use super::faults::{DrainSpec, FaultKind, FaultStats, ResilienceSpec, RetryPolicy};
use super::fleet::{aggregate, FleetConfig, FleetMetrics, RouterPolicy};
use super::kv::KvCache;
use super::metrics::RequestOutcome;
use super::sched::Scheduler;
use super::stream::{RequestStream, TimedRequest};
use super::telemetry::{profile, BufferSink, EventKind, SharedSink};
use super::{SimConfig, SimProbe};

/// What a router or admission policy may observe about one replica at
/// a decision point: a read-only snapshot, so policies can never
/// perturb the simulation and determinism is trivial.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaObs {
    /// The replica's local clock (s).
    pub clock_s: f64,
    /// Cumulative time inside iterations (s) — the rebalance signal.
    pub busy_s: f64,
    /// Offered requests not yet admitted.
    pub queue_depth: usize,
    /// Outstanding context + output tokens (the legacy JSQ signal).
    pub backlog_tokens: u64,
    /// Prompt tokens still to prefill before every known request has
    /// its first token (queued prompts + in-flight remainders).
    pub pending_prefill_tokens: u64,
    /// Unallocated KV capacity (whole free blocks, in tokens).
    pub kv_free_tokens: u64,
    /// Admitted requests still prefilling.
    pub n_prefilling: usize,
    /// Admitted requests in their decode phase.
    pub n_decoding: usize,
    /// Health flag maintained by the fault driver: `true` while the
    /// replica is crashed and has not yet recovered. Always `false`
    /// outside fault injection, so existing routers are unaffected.
    pub down: bool,
}

/// Snapshot one replica for a front-end decision (the queue/running
/// counters come from one traversal — `Scheduler::frontend_counters`).
pub fn observe(s: &Scheduler) -> ReplicaObs {
    let c = s.frontend_counters();
    ReplicaObs {
        clock_s: s.clock(),
        busy_s: s.busy_s(),
        queue_depth: s.queue_depth(),
        backlog_tokens: c.backlog_tokens,
        pending_prefill_tokens: c.pending_prefill_tokens,
        kv_free_tokens: s.kv_free_tokens(),
        n_prefilling: c.n_prefilling,
        n_decoding: c.n_decoding,
        down: false,
    }
}

/// Per-request replica selection. Implementations must be
/// deterministic functions of their own state and the observations.
pub trait Router {
    fn name(&self) -> &'static str;
    /// Pick the replica index for `req`; `reps` is never empty.
    fn route(&mut self, req: &TimedRequest, reps: &[ReplicaObs]) -> usize;
}

/// Blind rotation (the legacy `RouterPolicy::RoundRobin` match arm).
#[derive(Debug, Default)]
pub struct RoundRobinRouter {
    next: usize,
}

impl Router for RoundRobinRouter {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(&mut self, _req: &TimedRequest, reps: &[ReplicaObs]) -> usize {
        let k = self.next % reps.len();
        self.next += 1;
        k
    }
}

/// Fewest outstanding tokens, ties to the lowest index (the legacy
/// `RouterPolicy::JoinShortestQueue` match arm; also the intra-pool
/// policy of the disaggregated router).
#[derive(Debug, Default)]
pub struct JsqRouter;

impl Router for JsqRouter {
    fn name(&self) -> &'static str {
        "jsq"
    }

    fn route(&mut self, _req: &TimedRequest, reps: &[ReplicaObs]) -> usize {
        let mut best = 0usize;
        let mut best_backlog = u64::MAX;
        for (i, o) in reps.iter().enumerate() {
            if o.backlog_tokens < best_backlog {
                best_backlog = o.backlog_tokens;
                best = i;
            }
        }
        best
    }
}

/// Backlog-aware routing that additionally skips replicas without KV
/// headroom for the request's approximate footprint — prompt plus
/// outputs plus one decode slot; block rounding and shared-prefix
/// skips are not modeled, so this is a routing heuristic, not the
/// scheduler's exact `can_ever_fit` test — while any replica has room
/// (falling back to plain JSQ when none does). The first policy added
/// through the trait rather than the fleet loop; reachable as
/// `RouterPolicy::KvAware`. With ample KV it is exactly JSQ.
#[derive(Debug, Default)]
pub struct KvAwareRouter;

impl Router for KvAwareRouter {
    fn name(&self) -> &'static str {
        "kv-aware"
    }

    fn route(&mut self, req: &TimedRequest, reps: &[ReplicaObs]) -> usize {
        let need = req.input_len.max(1) + req.output_len.max(1) + 1;
        let mut best: Option<(u64, usize)> = None;
        for (i, o) in reps.iter().enumerate() {
            if o.kv_free_tokens >= need && best.map_or(true, |(b, _)| o.backlog_tokens < b) {
                best = Some((o.backlog_tokens, i));
            }
        }
        match best {
            Some((_, i)) => i,
            None => JsqRouter.route(req, reps),
        }
    }
}

/// The trait impl behind a policy enum value. `PrefillDecode` is a
/// two-pool *structure*, not a per-request pick: its intra-pool
/// routing is [`JsqRouter`], which is what this returns for it.
pub fn router_for(policy: RouterPolicy) -> Box<dyn Router> {
    match policy {
        RouterPolicy::RoundRobin => Box::<RoundRobinRouter>::default(),
        RouterPolicy::KvAware => Box::<KvAwareRouter>::default(),
        RouterPolicy::JoinShortestQueue | RouterPolicy::PrefillDecode => {
            Box::<JsqRouter>::default()
        }
    }
}

/// Front-door admission policy.
#[derive(Debug, Clone, Copy)]
pub enum AdmissionPolicy {
    /// The pre-refactor behavior: only requests that can never fit the
    /// KV budget are rejected (by the scheduler, at arrival).
    ArrivalReject,
    /// SLO-aware load shedding: additionally shed any request whose
    /// estimated TTFT on its routed replica ([`estimate_ttft`], using
    /// the probe-calibrated prefill rate) exceeds
    /// `margin * slo.ttft_s`. `margin = f64::INFINITY` never sheds and
    /// is bitwise-identical to `ArrivalReject`.
    SloShed { probe: SimProbe, margin: f64 },
}

impl AdmissionPolicy {
    pub fn name(&self) -> String {
        match self {
            AdmissionPolicy::ArrivalReject => "arrival-reject".into(),
            AdmissionPolicy::SloShed { margin, .. } => format!("slo-shed x{margin:.2}"),
        }
    }

    /// Should the front end shed this request, given the observation
    /// of the replica the router chose?
    fn sheds(&self, req: &TimedRequest, obs: &ReplicaObs, cfg: &SimConfig) -> bool {
        match self {
            AdmissionPolicy::ArrivalReject => false,
            AdmissionPolicy::SloShed { probe, margin } => {
                estimate_ttft(obs, req.input_len, probe) > margin * cfg.slo.ttft_s
            }
        }
    }
}

/// First-order TTFT estimate for a request joining a replica in state
/// `obs`: the backlog's prefill tokens (plus this prompt) drain at the
/// probe-calibrated prefill rate, while co-resident decodes add their
/// share of one decode iteration. Deliberately cheap, monotone in the
/// backlog and deterministic — it is a shedding signal, not a nested
/// simulation.
pub fn estimate_ttft(obs: &ReplicaObs, input_len: u64, probe: &SimProbe) -> f64 {
    let prefill_rate = probe.mean_in.max(1) as f64 / probe.t_prefill_s.max(1e-12);
    let backlog = (obs.pending_prefill_tokens + input_len.max(1)) as f64;
    let decode_tax =
        probe.t_decode_iter_s * obs.n_decoding as f64 / probe.concurrency.max(1) as f64;
    backlog / prefill_rate + decode_tax
}

/// Decode-pool rebalancing knobs.
#[derive(Debug, Clone, Copy)]
pub struct RebalanceSpec {
    /// Trigger threshold on busy-time imbalance `(max - min) / mean`
    /// across the pool. `f64::INFINITY` never triggers and is
    /// bitwise-identical to rebalancing off.
    pub imbalance_threshold: f64,
    /// KV handoff cost per migrated token (block-rounded), s/token.
    pub handoff_s_per_token: f64,
}

impl RebalanceSpec {
    pub fn new(imbalance_threshold: f64, handoff_s_per_token: f64) -> Self {
        RebalanceSpec {
            imbalance_threshold: imbalance_threshold.max(0.0),
            handoff_s_per_token: handoff_s_per_token.max(0.0),
        }
    }
}

/// The fleet front end: admission policy plus optional decode-pool
/// rebalancing. (The router comes from the fleet shape; see
/// [`router_for`].)
#[derive(Debug, Clone)]
pub struct Frontend {
    pub admission: AdmissionPolicy,
    pub rebalance: Option<RebalanceSpec>,
}

impl Frontend {
    /// Today's front end: legacy arrival-time rejection, no
    /// rebalancing. With this, [`simulate_fleet_frontend`] is
    /// bitwise-equal to the pre-refactor `simulate_fleet`.
    pub fn baseline() -> Self {
        Frontend {
            admission: AdmissionPolicy::ArrivalReject,
            rebalance: None,
        }
    }

    /// SLO-aware shedding at `margin` TTFT multiples, no rebalancing.
    pub fn with_shedding(probe: SimProbe, margin: f64) -> Self {
        Frontend {
            admission: AdmissionPolicy::SloShed { probe, margin },
            rebalance: None,
        }
    }

    pub fn with_rebalance(mut self, spec: RebalanceSpec) -> Self {
        self.rebalance = Some(spec);
        self
    }

    pub fn describe(&self) -> String {
        match self.rebalance {
            Some(rb) => format!(
                "{} + rebal>{:.2}",
                self.admission.name(),
                rb.imbalance_threshold
            ),
            None => self.admission.name(),
        }
    }
}

/// One in-flight front-end migration: the request's KV context lands
/// on replica `dst` at time `t`.
struct PendingMigration {
    t: f64,
    id: usize,
    dst: usize,
    ctx: u64,
    rest: u64,
}

/// Origin timings of a rebalanced request, recorded at its *first*
/// extraction: aggregation stitches them over the final holder's
/// completion so fleet-level TTFT/TPOT span the whole journey.
struct Origin {
    arrival_s: f64,
    input_len: u64,
    output_len: u64,
    first_token_s: f64,
}

/// A routed pool of replicas with optional decode-pool rebalancing:
/// the deterministic event driver shared by the homogeneous fleet and
/// each stage of the disaggregated one.
struct Pool<'a> {
    reps: Vec<Scheduler<'a>>,
    router: Box<dyn Router>,
    rebalance: Option<RebalanceSpec>,
    cfg: SimConfig,
    /// Undelivered rebalance migrations in an [`EventHeap`] keyed by
    /// `(t, id)` — O(log n) push/pop, draining the exact sequence of
    /// the sorted-`Vec` insert convention it replaces.
    pending: EventHeap<PendingMigration>,
    origins: HashMap<usize, Origin>,
    n_rebalanced: usize,
    /// Safety valve on total migrations (rebalancing moves work toward
    /// idler replicas, so it terminates; the cap bounds pathological
    /// configurations anyway).
    migration_cap: usize,
    /// Per-replica crash flags, set/cleared by the fault driver. All
    /// `false` outside fault injection, where every health check
    /// degenerates to the pre-fault behavior.
    down: Vec<bool>,
    /// Telemetry sink for pool-level events (sheds, failures, fault
    /// instants); `None` by default. See [`Pool::set_sink`].
    sink: Option<SharedSink>,
    /// Trace replica index of `reps[0]` (a disaggregated decode pool's
    /// replicas number after the prefill pool's).
    replica_base: usize,
    /// Worker threads for [`Pool::advance_all`]'s parallel replica
    /// stepping (`COMPASS_THREADS`-aware, see
    /// [`crate::cost::engine::default_threads`]); `1` forces the serial
    /// loop.
    threads: usize,
}

/// A drained pool: per-replica metrics plus per-request outcomes
/// (final holder only for rebalanced requests) and the origin records
/// needed to stitch them.
struct PoolResult {
    per_replica: Vec<super::metrics::ServingMetrics>,
    outcomes: Vec<(usize, RequestOutcome)>,
    /// Final-holder replica of each `outcomes` entry (parallel vector);
    /// the disaggregated driver attributes handoff-link telemetry to it.
    outcome_reps: Vec<usize>,
    origins: HashMap<usize, Origin>,
    n_rebalanced: usize,
}

impl<'a> Pool<'a> {
    fn new(
        reps: Vec<Scheduler<'a>>,
        router: Box<dyn Router>,
        rebalance: Option<RebalanceSpec>,
        cfg: SimConfig,
        migration_cap: usize,
    ) -> Self {
        let n = reps.len();
        Pool {
            reps,
            router,
            rebalance,
            cfg,
            pending: EventHeap::new(),
            origins: HashMap::new(),
            n_rebalanced: 0,
            migration_cap,
            down: vec![false; n],
            sink: None,
            replica_base: 0,
            threads: crate::cost::engine::default_threads(),
        }
    }

    /// Attach a telemetry sink to every replica scheduler (as trace
    /// replicas `replica_base..replica_base + reps.len()`) and keep a
    /// handle for the pool-level events the schedulers cannot see
    /// (front-door sheds, crash failures, fault instants). Disabled
    /// sinks are dropped, keeping the untraced path free.
    fn set_sink(&mut self, sink: &SharedSink, replica_base: usize) {
        if !sink.lock().unwrap().enabled() {
            return;
        }
        self.replica_base = replica_base;
        for (i, s) in self.reps.iter_mut().enumerate() {
            s.set_sink(sink.clone(), replica_base + i);
        }
        self.sink = Some(sink.clone());
    }

    /// Record a pool-level request event against local replica
    /// `local_rep` (trace replica `replica_base + local_rep`).
    fn emit(&self, local_rep: usize, t_s: f64, ext_id: usize, kind: EventKind) {
        if let Some(sink) = &self.sink {
            sink.lock()
                .unwrap()
                .event(self.replica_base + local_rep, t_s, ext_id, kind);
        }
    }

    /// Record a replica-level instant (crash/drain/straggler/link).
    fn instant(&self, local_rep: usize, t_s: f64, label: &'static str) {
        if let Some(sink) = &self.sink {
            sink.lock()
                .unwrap()
                .instant(self.replica_base + local_rep, t_s, label);
        }
    }

    fn observations(&self) -> Vec<ReplicaObs> {
        self.reps.iter().map(observe).collect()
    }

    /// Advance every replica clock to `t`. Between consecutive
    /// front-end events the replicas are independent — they share no
    /// mutable state except the cost memo, and `BatchCoster::cost` is a
    /// pure deterministic function of its composition key (the memo
    /// lock is held across the whole miss computation, so a shape is
    /// never costed twice and hit/miss counters stay order-independent)
    /// — so lagging replicas step concurrently on scoped threads with
    /// results bitwise-equal to the serial loop.
    ///
    /// Telemetry would otherwise interleave nondeterministically, so
    /// while threads run, each replica emits into a private
    /// [`BufferSink`] that is replayed into the real sink in replica
    /// index order afterwards: exactly the byte stream of the serial
    /// loop (replica 0 fully advanced, then replica 1, ...). The
    /// serial path is kept verbatim for single-threaded runs, a single
    /// lagging replica, and self-time profiling (the profiler's
    /// accumulators are thread-local).
    ///
    /// The horizon `t` is also what keeps decode fast-forward honest:
    /// every external event — arrival, migration delivery, fault
    /// instant, drain deadline — reaches a replica as an `advance_to`
    /// horizon, and [`Scheduler::try_fast_forward`] re-checks it per
    /// replayed iteration, so a coalesced stretch can never overshoot
    /// an event this control plane will deliver.
    fn advance_all(&mut self, t: f64) {
        let lagging = self.reps.iter().filter(|s| s.needs_advance(t)).count();
        if self.threads <= 1 || lagging <= 1 || profile::enabled() {
            for s in self.reps.iter_mut() {
                s.advance_to(t);
            }
            return;
        }
        let traced = self.sink.is_some();
        let mut bufs: Vec<Arc<Mutex<BufferSink>>> = Vec::new();
        let mut saved: Vec<Option<SharedSink>> = Vec::new();
        if traced {
            for s in self.reps.iter_mut() {
                let buf = Arc::new(Mutex::new(BufferSink::new()));
                bufs.push(buf.clone());
                saved.push(s.swap_sink(Some(buf)));
            }
        }
        let chunk = self.reps.len().div_ceil(self.threads.min(lagging));
        std::thread::scope(|scope| {
            for slab in self.reps.chunks_mut(chunk.max(1)) {
                scope.spawn(move || {
                    for s in slab {
                        s.advance_to(t);
                    }
                });
            }
        });
        if traced {
            for (s, orig) in self.reps.iter_mut().zip(saved) {
                s.swap_sink(orig);
            }
            let sink = self.sink.as_ref().unwrap();
            let mut sink = sink.lock().unwrap();
            for buf in &bufs {
                buf.lock().unwrap().replay(&mut *sink);
            }
        }
    }

    /// Route `req` and return the chosen replica plus its observation
    /// (for the admission estimate).
    fn route(&mut self, req: &TimedRequest) -> (usize, ReplicaObs) {
        let obs = self.observations();
        let k = self.router.route(req, &obs).min(obs.len() - 1);
        (k, obs[k])
    }

    fn push_migration(&mut self, m: PendingMigration) {
        self.pending.push(m.t, m.id, m);
    }

    /// Deliver every pending migration due by `t`, in (time, id)
    /// order, interleaving all replica clocks exactly like arrivals.
    fn deliver_due(&mut self, t: f64) {
        while let Some((_, _, m)) = self.pending.pop_due(t) {
            self.advance_all(m.t);
            self.reps[m.dst].inject_migrated(m.id, m.t, m.ctx, m.rest);
        }
    }

    /// Under busy-time imbalance, extract the youngest mid-decode
    /// request from the busiest replica and schedule its block-rounded
    /// KV handoff to the least busy one. At most one migration per
    /// front-end event keeps churn bounded and deterministic.
    fn maybe_rebalance(&mut self, t: f64) {
        let Some(rb) = self.rebalance else { return };
        if self.reps.len() < 2 || self.n_rebalanced >= self.migration_cap {
            return;
        }
        let busy: Vec<f64> = self.reps.iter().map(|s| s.busy_s()).collect();
        let mean = busy.iter().sum::<f64>() / busy.len() as f64;
        if mean <= 1e-12 {
            return;
        }
        // first-max / first-min over the *healthy* replicas only: a
        // crashed replica is neither a source (its requests already
        // failed) nor a destination (a migration there would die). With
        // every replica up this picks exactly what the unconditional
        // scan did, keeping the zero-fault path bitwise-identical.
        let (mut src, mut dst) = (None::<usize>, None::<usize>);
        for i in 0..busy.len() {
            if self.down[i] {
                continue;
            }
            if src.map_or(true, |s| busy[i] > busy[s]) {
                src = Some(i);
            }
            if dst.map_or(true, |d| busy[i] < busy[d]) {
                dst = Some(i);
            }
        }
        let (Some(src), Some(dst)) = (src, dst) else {
            return;
        };
        if src == dst || (busy[src] - busy[dst]) / mean <= rb.imbalance_threshold {
            return;
        }
        // only migrate toward strictly less outstanding work, or the
        // handoff would just shuffle the bottleneck around
        if self.reps[src].backlog_tokens() <= self.reps[dst].backlog_tokens() {
            return;
        }
        // never extract a request the destination could not hold
        // (heterogeneous replicas can have smaller KV capacities) — a
        // migration must not turn into a rejection that discards
        // already-generated work
        let Some((ctx, rest)) = self.reps[src].peek_youngest_decoding() else {
            return;
        };
        if !self.reps[dst].kv_can_ever_fit(ctx, rest) {
            return;
        }
        let Some(ex) = self.reps[src].extract_youngest_decoding() else {
            return;
        };
        let depart = self.reps[src].clock().max(t);
        let link_tokens = self.cfg.kv.block_round(ex.context_len);
        let arrive = depart + link_tokens as f64 * rb.handoff_s_per_token.max(0.0);
        // first extraction records the true origin; later hops keep it
        self.origins.entry(ex.ext_id).or_insert(Origin {
            arrival_s: ex.arrival_s,
            input_len: ex.input_len,
            output_len: ex.output_len,
            first_token_s: ex.first_token_s,
        });
        self.n_rebalanced += 1;
        self.push_migration(PendingMigration {
            t: arrive,
            id: ex.ext_id,
            dst,
            ctx: ex.context_len,
            rest: ex.rest,
        });
    }

    /// Deliver the remaining migrations (each delivery may re-trigger
    /// the rebalancer; work only ever moves toward idler replicas, so
    /// this terminates), drain every replica, and collapse the pool.
    fn finish(mut self) -> PoolResult {
        while let Some((_, _, m)) = self.pending.pop() {
            self.advance_all(m.t);
            self.reps[m.dst].inject_migrated(m.id, m.t, m.ctx, m.rest);
            self.maybe_rebalance(m.t);
        }
        // the final drain is `advance_to(∞)` per replica — exactly
        // `run_to_end`, but through `advance_all` so independent
        // replicas drain in parallel
        self.advance_all(f64::INFINITY);
        let mut per_replica = Vec::with_capacity(self.reps.len());
        let mut outcomes: Vec<(usize, RequestOutcome)> = Vec::new();
        let mut outcome_reps: Vec<usize> = Vec::new();
        for (ri, s) in self.reps.into_iter().enumerate() {
            let r = s.finish();
            outcome_reps.extend(std::iter::repeat(ri).take(r.outcomes.len()));
            outcomes.extend(r.outcomes);
            per_replica.push(r.metrics);
        }
        PoolResult {
            per_replica,
            outcomes,
            outcome_reps,
            origins: self.origins,
            n_rebalanced: self.n_rebalanced,
        }
    }
}

/// Stitch a pool's per-request outcomes over the rebalancer's origin
/// records: a migrated request keeps its original arrival, prompt
/// length and first-token time, and takes the finish (or rejection)
/// from its final holder. The identity map when nothing migrated.
fn stitch(
    outcomes: &[(usize, RequestOutcome)],
    origins: &HashMap<usize, Origin>,
) -> Vec<RequestOutcome> {
    stitch_keyed(outcomes, origins)
        .into_iter()
        .map(|(_, o)| o)
        .collect()
}

/// [`stitch`] keeping the request ids: the fault driver needs them to
/// match a final outcome back to its retry record.
fn stitch_keyed(
    outcomes: &[(usize, RequestOutcome)],
    origins: &HashMap<usize, Origin>,
) -> Vec<(usize, RequestOutcome)> {
    outcomes
        .iter()
        .map(|&(id, o)| {
            let o = match origins.get(&id) {
                Some(g) => RequestOutcome {
                    arrival_s: g.arrival_s,
                    input_len: g.input_len,
                    output_len: g.output_len,
                    first_token_s: Some(g.first_token_s),
                    finish_s: o.finish_s,
                    rejected: o.rejected,
                },
                None => o,
            };
            (id, o)
        })
        .collect()
}

/// One cost memo per distinct hardware configuration: replicas with
/// equal `(model, hw, policy)` share it, so a batch shape costed — or
/// GA-searched — anywhere is never re-simulated on an identical
/// replica. Sharing is bit-exact (the memo is composition-keyed and
/// each entry order-independent), so a homogeneous fleet behaves
/// exactly as with PR 3's single shared coster.
fn pool_costers<'a>(
    model: &'a ModelSpec,
    hws: &'a [HwConfig],
    cfg: &SimConfig,
) -> Vec<Arc<Mutex<BatchCoster<'a>>>> {
    let mut out: Vec<Arc<Mutex<BatchCoster<'a>>>> = Vec::with_capacity(hws.len());
    for (i, hw) in hws.iter().enumerate() {
        if let Some(j) = hws[..i].iter().position(|h| h == hw) {
            out.push(out[j].clone());
        } else {
            out.push(Arc::new(Mutex::new(BatchCoster::new(
                model,
                hw,
                cfg.policy,
                cfg.eval_blocks,
                cfg.ctx_bucket,
                cfg.kv.dtype,
            ))));
        }
    }
    out
}

fn shed_outcome(r: &TimedRequest) -> RequestOutcome {
    RequestOutcome {
        arrival_s: r.arrival_s,
        input_len: r.input_len.max(1),
        output_len: r.output_len.max(1),
        first_token_s: None,
        finish_s: None,
        rejected: true,
    }
}

/// Replay `stream` across a fleet with per-replica hardware and an
/// explicit front end. `hws` must hold one entry per replica (prefill
/// pool first for disaggregated shapes); with [`Frontend::baseline`]
/// and identical hardware this is bitwise-equal to
/// [`super::fleet::simulate_fleet`]. Deterministic: identical inputs
/// give bit-identical output.
pub fn simulate_fleet_frontend(
    stream: &RequestStream,
    model: &ModelSpec,
    hws: &[HwConfig],
    cfg: &SimConfig,
    fleet: &FleetConfig,
    fe: &Frontend,
) -> FleetMetrics {
    run_fleet_frontend(stream, model, hws, cfg, fleet, fe, None)
}

/// [`simulate_fleet_frontend`] with a telemetry sink attached to every
/// replica (prefill pool first for disaggregated shapes, so trace
/// replica indices match `hws`). All emission happens after each step's
/// arithmetic, so the metrics are bitwise-identical to the untraced run.
pub fn simulate_fleet_frontend_traced(
    stream: &RequestStream,
    model: &ModelSpec,
    hws: &[HwConfig],
    cfg: &SimConfig,
    fleet: &FleetConfig,
    fe: &Frontend,
    sink: &SharedSink,
) -> FleetMetrics {
    run_fleet_frontend(stream, model, hws, cfg, fleet, fe, Some(sink))
}

fn run_fleet_frontend(
    stream: &RequestStream,
    model: &ModelSpec,
    hws: &[HwConfig],
    cfg: &SimConfig,
    fleet: &FleetConfig,
    fe: &Frontend,
    sink: Option<&SharedSink>,
) -> FleetMetrics {
    assert_eq!(
        hws.len(),
        fleet.total_replicas(),
        "one HwConfig per replica (prefill pool first for disaggregated shapes)"
    );
    match fleet.router {
        RouterPolicy::PrefillDecode => run_disaggregated(stream, model, hws, cfg, fleet, fe, sink),
        _ => run_homogeneous(stream, model, hws, cfg, fleet, fe, sink),
    }
}

fn run_homogeneous(
    stream: &RequestStream,
    model: &ModelSpec,
    hws: &[HwConfig],
    cfg: &SimConfig,
    fleet: &FleetConfig,
    fe: &Frontend,
    sink: Option<&SharedSink>,
) -> FleetMetrics {
    let n_rep = fleet.n_replicas.max(1);
    let costers = pool_costers(model, &hws[..n_rep], cfg);
    let reps: Vec<Scheduler> = hws[..n_rep]
        .iter()
        .zip(&costers)
        .map(|(hw, c)| Scheduler::with_coster(model, hw, cfg, c.clone()))
        .collect();
    let mut pool = Pool::new(
        reps,
        router_for(fleet.router),
        fe.rebalance,
        *cfg,
        4 * stream.requests.len() + 16,
    );
    if let Some(s) = sink {
        pool.set_sink(s, 0);
    }
    let mut shed: Vec<RequestOutcome> = Vec::new();
    for r in &stream.requests {
        pool.deliver_due(r.arrival_s);
        pool.advance_all(r.arrival_s);
        let (k, obs) = pool.route(r);
        if fe.admission.sheds(r, &obs, cfg) {
            pool.emit(k, r.arrival_s, r.id, EventKind::Shed);
            shed.push(shed_outcome(r));
        } else {
            pool.reps[k].inject(r.id, r.arrival_s, r.input_len, r.output_len);
        }
        pool.maybe_rebalance(r.arrival_s);
    }
    let res = pool.finish();
    let mut outcomes = stitch(&res.outcomes, &res.origins);
    let n_shed = shed.len();
    outcomes.extend(shed);
    aggregate(
        res.per_replica,
        outcomes,
        cfg,
        n_shed,
        res.n_rebalanced,
        FaultStats::default(),
    )
}

/// A prefill-complete request waiting on its KV transfer.
struct Migration {
    t: f64,
    id: usize,
    /// Context tokens to materialize at the decode replica (prompt plus
    /// the first generated token).
    ctx: u64,
    /// Output tokens still to decode.
    rest: u64,
}

fn run_disaggregated(
    stream: &RequestStream,
    model: &ModelSpec,
    hws: &[HwConfig],
    cfg: &SimConfig,
    fleet: &FleetConfig,
    fe: &Frontend,
    sink: Option<&SharedSink>,
) -> FleetMetrics {
    let sink = sink.filter(|s| s.lock().unwrap().enabled());
    let (n_pre, n_dec) = (fleet.n_prefill.max(1), fleet.n_decode.max(1));
    let costers = pool_costers(model, hws, cfg);
    // spec-aware footprint probe (paging + sharing + dtype), the same
    // test every scheduler applies at arrival
    let fit_probe = KvCache::new(cfg.kv, cfg.kv_budget(model).max(2));
    // --- stage 1: prompts JSQ-routed over the prefill pool, truncated
    // to a single output token (emitted at prefill completion). A
    // request whose *full* footprint can never fit is injected with its
    // real output length so the scheduler rejects it at arrival with
    // zero compute — the same arrival-time rejection the homogeneous
    // routers apply, keeping the policies comparable on one stream.
    // SLO-aware shedding acts here too: the TTFT is produced by the
    // prefill pool, so its backlog drives the estimate ---
    let pre_reps: Vec<Scheduler> = hws[..n_pre]
        .iter()
        .zip(&costers[..n_pre])
        .map(|(hw, c)| Scheduler::with_coster(model, hw, cfg, c.clone()))
        .collect();
    let mut pre = Pool::new(pre_reps, Box::<JsqRouter>::default(), None, *cfg, 0);
    if let Some(s) = sink {
        pre.set_sink(s, 0);
    }
    let mut shed: Vec<RequestOutcome> = Vec::new();
    for r in &stream.requests {
        pre.advance_all(r.arrival_s);
        let (k, obs) = pre.route(r);
        if fe.admission.sheds(r, &obs, cfg) {
            pre.emit(k, r.arrival_s, r.id, EventKind::Shed);
            shed.push(shed_outcome(r));
            continue;
        }
        let out = r.output_len.max(1);
        if !fit_probe.can_ever_fit(r.input_len.max(1), out) {
            pre.reps[k].inject(r.id, r.arrival_s, r.input_len, out);
        } else {
            pre.reps[k].inject(r.id, r.arrival_s, r.input_len, 1);
        }
    }
    let pre_res = pre.finish();
    let mut per_replica = pre_res.per_replica;
    let pre_outcomes = pre_res.outcomes;
    let pre_outcome_reps = pre_res.outcome_reps;

    // --- KV handoff: completed prefills migrate to the decode pool
    // after `ctx * handoff_s_per_token` seconds, in global time order ---
    let out_len_of: HashMap<usize, u64> = stream
        .requests
        .iter()
        .map(|r| (r.id, r.output_len.max(1)))
        .collect();
    // handoffs drain in (t, id) order straight from the heap — ids are
    // unique, so the order is exactly the old `sort_by(t, then id)`
    let mut migs: EventHeap<Migration> = EventHeap::new();
    for (i, &(id, o)) in pre_outcomes.iter().enumerate() {
        let (Some(finish), false) = (o.finish_s, o.rejected) else {
            continue;
        };
        let rest = out_len_of.get(&id).copied().unwrap_or(1).saturating_sub(1);
        if rest == 0 {
            continue; // single-token request: done at prefill
        }
        let ctx = o.input_len + 1;
        // whole blocks migrate: the link moves the context rounded up to
        // the KV block size (exact at block_tokens = 1)
        let link_tokens = cfg.kv.block_round(ctx);
        // the handoff link opens at the prefill replica's finish time;
        // the matching MigrateIn comes from the decode-side scheduler
        if let Some(s) = sink {
            s.lock()
                .unwrap()
                .event(pre_outcome_reps[i], finish, id, EventKind::MigrateOut);
        }
        let t = finish + link_tokens as f64 * fleet.handoff_s_per_token.max(0.0);
        migs.push(t, id, Migration { t, id, ctx, rest });
    }

    // --- stage 2: migrations JSQ-routed over the decode pool, with
    // optional decode-pool rebalancing between its replicas ---
    let dec_reps: Vec<Scheduler> = hws[n_pre..]
        .iter()
        .zip(&costers[n_pre..])
        .map(|(hw, c)| Scheduler::with_coster(model, hw, cfg, c.clone()))
        .collect();
    let mut dec = Pool::new(
        dec_reps,
        Box::<JsqRouter>::default(),
        fe.rebalance,
        *cfg,
        4 * migs.len() + 16,
    );
    if let Some(s) = sink {
        dec.set_sink(s, n_pre);
    }
    while let Some((_, _, m)) = migs.pop() {
        dec.deliver_due(m.t);
        dec.advance_all(m.t);
        let req = TimedRequest {
            id: m.id,
            arrival_s: m.t,
            input_len: m.ctx,
            output_len: m.rest,
        };
        let (k, _) = dec.route(&req);
        dec.reps[k].inject_migrated(m.id, m.t, m.ctx, m.rest);
        dec.maybe_rebalance(m.t);
    }
    let dec_res = dec.finish();
    per_replica.extend(dec_res.per_replica);

    // --- stitch per-request outcomes across the two stages (the final
    // decode holder carries the finish even after rebalancing) ---
    let dec_by_id: HashMap<usize, RequestOutcome> = dec_res.outcomes.into_iter().collect();
    let mut outcomes: Vec<RequestOutcome> = pre_outcomes
        .iter()
        .map(|&(id, p)| {
            let out_len = out_len_of.get(&id).copied().unwrap_or(1);
            let mut o = RequestOutcome {
                arrival_s: p.arrival_s,
                input_len: p.input_len,
                output_len: out_len,
                first_token_s: p.first_token_s,
                finish_s: if out_len == 1 { p.finish_s } else { None },
                rejected: p.rejected,
            };
            if let Some(d) = dec_by_id.get(&id) {
                // decode-stage rejection (context can never fit there)
                // makes the whole request rejected at fleet level
                o.rejected = p.rejected || d.rejected;
                o.finish_s = d.finish_s;
            }
            o
        })
        .collect();
    let n_shed = shed.len();
    outcomes.extend(shed);
    aggregate(
        per_replica,
        outcomes,
        cfg,
        n_shed,
        dec_res.n_rebalanced,
        FaultStats::default(),
    )
}

/// One scheduled fault-driver event, expanded from a [`FaultSchedule`]:
/// a crash (optionally preceded by a proactive drain), the start of a
/// straggler window, or a fleet-wide link-factor change.
#[derive(Debug, Clone, Copy)]
enum FaultEv {
    Crash { rep: usize, recovery_s: f64 },
    Drain { rep: usize },
    Straggle { rep: usize, until_s: f64, slowdown: f64 },
    LinkSet { factor: f64 },
}

/// Lifecycle record of one stream request under fault injection: its
/// original arrival (final outcomes always report it), how many times it
/// has been offered to the fleet, and whether it ever re-entered.
struct Track {
    req: TimedRequest,
    attempts: usize,
    retried: bool,
}

/// The failure-aware front end: wraps a [`Pool`] with health tracking,
/// capped-backoff retry, proactive drain, and the fault event loop.
/// With an empty schedule and retry disabled, every code path here
/// reduces to [`run_homogeneous`]'s exact call sequence — the zero-fault
/// bitwise anchor in `rust/tests/fault_properties.rs` holds the
/// subsystem to that.
struct FaultDriver<'a> {
    pool: Pool<'a>,
    admission: AdmissionPolicy,
    cfg: SimConfig,
    retry: RetryPolicy,
    drain: Option<DrainSpec>,
    failover: bool,
    tracks: HashMap<usize, Track>,
    /// Requests waiting out their backoff, drained in (due, id) order.
    retryq: EventHeap<()>,
    shed_final: Vec<RequestOutcome>,
    lost_final: Vec<RequestOutcome>,
    /// Recovery deadline per replica (meaningful while `pool.down`).
    up_at: Vec<f64>,
    stats: FaultStats,
    n_shed: usize,
    /// Current fleet-wide KV-link degradation factor (1 = nominal).
    link_factor: f64,
    /// The rebalancer's nominal `handoff_s_per_token`, so link windows
    /// scale from the configured value rather than compounding.
    base_handoff: f64,
}

impl<'a> FaultDriver<'a> {
    /// Clear crash flags whose recovery deadline has passed. Recovery is
    /// lazy — checked at every event — so a replica rejoins (cold: its
    /// cache was rebuilt empty at the crash) at the first decision point
    /// after its deadline.
    fn refresh_health(&mut self, t: f64) {
        for k in 0..self.pool.down.len() {
            if self.pool.down[k] && t >= self.up_at[k] {
                self.pool.down[k] = false;
            }
        }
    }

    /// The per-event preamble, in exactly [`run_homogeneous`]'s order:
    /// deliver due migrations, then advance every replica clock.
    fn step_to(&mut self, t: f64) {
        self.refresh_health(t);
        self.pool.deliver_due(t);
        self.pool.advance_all(t);
    }

    fn push_retry(&mut self, due: f64, id: usize) {
        self.retryq.push(due, id, ());
    }

    /// A request's current attempt just died (crash-killed, migration
    /// into a crash, or routed into a dead replica with failover off):
    /// schedule a backoff retry if attempts remain, else count it
    /// permanently lost.
    fn fail(&mut self, id: usize, t: f64, rep: usize) {
        self.stats.n_failed += 1;
        // any in-flight migration origin died with the attempt; the
        // retry must not inherit its first-token time
        self.pool.origins.remove(&id);
        let tr = &self.tracks[&id];
        let (attempts, req) = (tr.attempts, tr.req);
        if attempts < self.retry.max_attempts {
            self.stats.n_retried += 1;
            self.pool.emit(rep, t, id, EventKind::Fail);
            self.push_retry(t + self.retry.delay_s(attempts), id);
        } else {
            self.stats.n_lost += 1;
            self.pool.emit(rep, t, id, EventKind::Loss);
            self.lost_final.push(RequestOutcome {
                arrival_s: req.arrival_s,
                input_len: req.input_len.max(1),
                output_len: req.output_len.max(1),
                first_token_s: None,
                finish_s: None,
                rejected: true,
            });
        }
    }

    /// The admission gate shed this offer: back off and retry if
    /// attempts remain, else it is a terminal shed (exactly the
    /// non-fault path when retry is disabled).
    fn shed_or_retry(&mut self, id: usize, t: f64, rep: usize) {
        let tr = &self.tracks[&id];
        let (attempts, req) = (tr.attempts, tr.req);
        if attempts < self.retry.max_attempts {
            self.stats.n_retried += 1;
            self.pool.emit(rep, t, id, EventKind::Fail);
            self.push_retry(t + self.retry.delay_s(attempts), id);
        } else {
            self.n_shed += 1;
            self.pool.emit(rep, t, id, EventKind::Shed);
            self.shed_final.push(shed_outcome(&req));
        }
    }

    /// Offer request `id` to the fleet at time `t` (its arrival, or a
    /// retry due-time). Routing, admission and injection follow
    /// [`run_homogeneous`] bit for bit when every replica is up.
    fn offer(&mut self, id: usize, t: f64) {
        let (input_len, output_len) = {
            let tr = self.tracks.get_mut(&id).unwrap();
            tr.attempts += 1;
            if tr.attempts > 1 {
                tr.retried = true;
            }
            (tr.req.input_len, tr.req.output_len)
        };
        let r = TimedRequest {
            id,
            arrival_s: t,
            input_len,
            output_len,
        };
        let mut obs = self.pool.observations();
        for (k, o) in obs.iter_mut().enumerate() {
            o.down = self.pool.down[k];
        }
        let k = if self.failover {
            // route over the healthy subset only; with every replica up
            // this is the identity remap of the plain route
            let healthy: Vec<usize> = (0..obs.len()).filter(|&k| !self.pool.down[k]).collect();
            if healthy.is_empty() {
                self.fail(id, t, 0);
                return;
            }
            let hobs: Vec<ReplicaObs> = healthy.iter().map(|&k| obs[k]).collect();
            healthy[self.pool.router.route(&r, &hobs).min(hobs.len() - 1)]
        } else {
            // failover disabled: the router is blind to health, and an
            // offer landing on a dead replica fails outright (JSQ is
            // pathological here — a crashed replica's empty backlog
            // attracts every request until it recovers)
            let k = self.pool.router.route(&r, &obs).min(obs.len() - 1);
            if self.pool.down[k] {
                self.fail(id, t, k);
                return;
            }
            k
        };
        if self.admission.sheds(&r, &obs[k], &self.cfg) {
            self.shed_or_retry(id, t, k);
        } else {
            self.pool.reps[k].inject(id, t, r.input_len, r.output_len);
        }
    }

    /// Crash replica `rep` at `t`: kill migrations in flight toward it,
    /// fail its queued + running requests (wiping its KV, shared prefix
    /// included), and mark it down until `t + recovery_s`.
    fn on_crash(&mut self, rep: usize, t: f64, recovery_s: f64) {
        self.step_to(t);
        // migrations in flight toward the crashed replica die in their
        // (t, id) delivery order; the survivors keep their positions
        for (_, _, m) in self.pool.pending.remove_where(|_, _, m| m.dst == rep) {
            self.fail(m.id, t, rep);
        }
        self.pool.instant(rep, t, "crash");
        let failed = self.pool.reps[rep].crash(t);
        self.pool.down[rep] = true;
        self.up_at[rep] = t + recovery_s.max(0.0);
        self.stats.n_crashes += 1;
        for f in failed {
            self.fail(f.ext_id, t, rep);
        }
    }

    /// Proactively evacuate up to `max_requests` mid-decode requests
    /// from `rep` ahead of its scheduled crash, via the block-rounded KV
    /// handoff path. Queued and still-prefilling requests stay (they
    /// have little KV to save and will retry after the crash).
    fn on_drain(&mut self, rep: usize, t: f64) {
        let Some(d) = self.drain else { return };
        if self.pool.down[rep] {
            return;
        }
        self.step_to(t);
        self.pool.instant(rep, t, "drain");
        for _ in 0..d.max_requests {
            let Some((ctx, rest)) = self.pool.reps[rep].peek_youngest_decoding() else {
                break;
            };
            // least-busy healthy destination with KV headroom
            let mut dst: Option<usize> = None;
            for k in 0..self.pool.reps.len() {
                if k == rep || self.pool.down[k] || !self.pool.reps[k].kv_can_ever_fit(ctx, rest)
                {
                    continue;
                }
                if dst.map_or(true, |b| self.pool.reps[k].busy_s() < self.pool.reps[b].busy_s())
                {
                    dst = Some(k);
                }
            }
            let Some(dst) = dst else { break };
            let Some(ex) = self.pool.reps[rep].extract_youngest_decoding() else {
                break;
            };
            let depart = self.pool.reps[rep].clock().max(t);
            let link_tokens = self.pool.cfg.kv.block_round(ex.context_len);
            let arrive =
                depart + link_tokens as f64 * (d.handoff_s_per_token * self.link_factor).max(0.0);
            self.pool.origins.entry(ex.ext_id).or_insert(Origin {
                arrival_s: ex.arrival_s,
                input_len: ex.input_len,
                output_len: ex.output_len,
                first_token_s: ex.first_token_s,
            });
            self.stats.n_drained += 1;
            self.pool.push_migration(PendingMigration {
                t: arrive,
                id: ex.ext_id,
                dst,
                ctx: ex.context_len,
                rest: ex.rest,
            });
        }
    }
}

/// [`simulate_fleet_frontend`] under a deterministic fault schedule and
/// resilience posture: replica crashes (KV wiped, in-flight requests
/// failed, recovery after a delay, cold rejoin), straggler slowdown
/// windows, fleet-wide KV-link degradation, health-aware routing with
/// optional failover, capped-exponential-backoff retry, and proactive
/// pre-crash drain. Deterministic: identical inputs (including the
/// schedule) give bit-identical output, and `ResilienceSpec::none()` is
/// bitwise-equal to [`simulate_fleet_frontend`].
///
/// Currently models homogeneous fleets only (any single-pool router);
/// fault injection for disaggregated prefill/decode shapes is future
/// work.
pub fn simulate_fleet_faults(
    stream: &RequestStream,
    model: &ModelSpec,
    hws: &[HwConfig],
    cfg: &SimConfig,
    fleet: &FleetConfig,
    fe: &Frontend,
    res: &ResilienceSpec,
) -> FleetMetrics {
    run_fleet_faults(stream, model, hws, cfg, fleet, fe, res, None)
}

/// [`simulate_fleet_faults`] with a telemetry sink attached: request
/// lifecycle spans plus failure events (`Fail`/`Loss`/`Shed`) and
/// replica instants (`crash`/`drain`/`straggler`/`link`). Emission
/// happens after each step's arithmetic, so the metrics are
/// bitwise-identical to the untraced run.
#[allow(clippy::too_many_arguments)]
pub fn simulate_fleet_faults_traced(
    stream: &RequestStream,
    model: &ModelSpec,
    hws: &[HwConfig],
    cfg: &SimConfig,
    fleet: &FleetConfig,
    fe: &Frontend,
    res: &ResilienceSpec,
    sink: &SharedSink,
) -> FleetMetrics {
    run_fleet_faults(stream, model, hws, cfg, fleet, fe, res, Some(sink))
}

#[allow(clippy::too_many_arguments)]
fn run_fleet_faults(
    stream: &RequestStream,
    model: &ModelSpec,
    hws: &[HwConfig],
    cfg: &SimConfig,
    fleet: &FleetConfig,
    fe: &Frontend,
    res: &ResilienceSpec,
    sink: Option<&SharedSink>,
) -> FleetMetrics {
    assert_eq!(
        hws.len(),
        fleet.total_replicas(),
        "one HwConfig per replica"
    );
    assert!(
        fleet.router != RouterPolicy::PrefillDecode,
        "fault injection currently models homogeneous fleets; disaggregated shapes are future work"
    );
    let n_rep = fleet.n_replicas.max(1);
    let costers = pool_costers(model, &hws[..n_rep], cfg);
    let reps: Vec<Scheduler> = hws[..n_rep]
        .iter()
        .zip(&costers)
        .map(|(hw, c)| Scheduler::with_coster(model, hw, cfg, c.clone()))
        .collect();
    let mut pool = Pool::new(
        reps,
        router_for(fleet.router),
        fe.rebalance,
        *cfg,
        4 * stream.requests.len() + 16,
    );
    if let Some(s) = sink {
        pool.set_sink(s, 0);
    }
    let mut drv = FaultDriver {
        pool,
        admission: fe.admission,
        cfg: *cfg,
        retry: res.retry,
        drain: res.drain,
        failover: res.failover,
        tracks: stream
            .requests
            .iter()
            .map(|r| {
                (
                    r.id,
                    Track {
                        req: *r,
                        attempts: 0,
                        retried: false,
                    },
                )
            })
            .collect(),
        retryq: EventHeap::new(),
        shed_final: Vec::new(),
        lost_final: Vec::new(),
        up_at: vec![0.0; n_rep],
        stats: FaultStats::default(),
        n_shed: 0,
        link_factor: 1.0,
        base_handoff: fe.rebalance.map_or(0.0, |rb| rb.handoff_s_per_token),
    };
    // expand the schedule into a time-ordered event heap; pushing with a
    // constant id makes the heap's FIFO seq the only tie-break, so
    // equal-t faults drain in schedule order and a drain stays ahead of
    // its crash — exactly the old stable sort by time
    let mut events: EventHeap<FaultEv> = EventHeap::new();
    for f in &res.schedule.faults {
        let rep = f.replica.min(n_rep - 1);
        match f.kind {
            FaultKind::Crash { recovery_s } => {
                if let Some(d) = res.drain {
                    events.push((f.t_s - d.lead_s).max(0.0), 0, FaultEv::Drain { rep });
                }
                events.push(f.t_s, 0, FaultEv::Crash { rep, recovery_s });
            }
            FaultKind::Straggler {
                duration_s,
                slowdown,
            } => {
                events.push(
                    f.t_s,
                    0,
                    FaultEv::Straggle {
                        rep,
                        until_s: f.t_s + duration_s,
                        slowdown,
                    },
                );
            }
            FaultKind::LinkDegrade { duration_s, factor } => {
                events.push(f.t_s, 0, FaultEv::LinkSet { factor });
                events.push(f.t_s + duration_s, 0, FaultEv::LinkSet { factor: 1.0 });
            }
        }
    }
    // three-way deterministic merge of fault events, stream arrivals and
    // retry due-times; ties resolve events < arrivals < retries so a
    // crash at an arrival instant kills before the arrival routes
    let mut arr_i = 0usize;
    loop {
        let te = events.peek_t().unwrap_or(f64::INFINITY);
        let ta = stream
            .requests
            .get(arr_i)
            .map_or(f64::INFINITY, |r| r.arrival_s);
        let tr = drv.retryq.peek_t().unwrap_or(f64::INFINITY);
        if te.is_infinite() && ta.is_infinite() && tr.is_infinite() {
            break;
        }
        if te <= ta && te <= tr {
            let (t, _, ev) = events.pop().unwrap();
            match ev {
                FaultEv::Crash { rep, recovery_s } => drv.on_crash(rep, t, recovery_s),
                FaultEv::Drain { rep } => drv.on_drain(rep, t),
                FaultEv::Straggle {
                    rep,
                    until_s,
                    slowdown,
                } => {
                    drv.step_to(t);
                    drv.pool.instant(rep, t, "straggler");
                    drv.pool.reps[rep].set_slowdown(until_s, slowdown);
                }
                FaultEv::LinkSet { factor } => {
                    drv.pool.instant(0, t, "link");
                    drv.link_factor = factor;
                    if let Some(rb) = drv.pool.rebalance.as_mut() {
                        rb.handoff_s_per_token = drv.base_handoff * factor;
                    }
                }
            }
        } else if ta <= tr {
            let r = stream.requests[arr_i];
            arr_i += 1;
            drv.step_to(r.arrival_s);
            drv.offer(r.id, r.arrival_s);
            drv.pool.maybe_rebalance(r.arrival_s);
        } else {
            let (t, id, ()) = drv.retryq.pop().unwrap();
            drv.step_to(t);
            drv.offer(id, t);
            drv.pool.maybe_rebalance(t);
        }
    }
    let FaultDriver {
        pool,
        tracks,
        shed_final,
        lost_final,
        stats,
        n_shed,
        ..
    } = drv;
    let pres = pool.finish();
    // a retried request's final outcome keeps its ORIGINAL arrival time
    // (TTFT spans downtime and backoff) while taking first-token and
    // finish truth from the attempt that actually completed; failed
    // attempts never produce outcomes, so nothing double-counts
    let mut outcomes: Vec<RequestOutcome> = Vec::with_capacity(stream.requests.len());
    for (id, mut o) in stitch_keyed(&pres.outcomes, &pres.origins) {
        if let Some(tr) = tracks.get(&id) {
            if tr.retried {
                o.arrival_s = tr.req.arrival_s;
                o.input_len = tr.req.input_len.max(1);
                o.output_len = tr.req.output_len.max(1);
            }
        }
        outcomes.push(o);
    }
    outcomes.extend(shed_final);
    outcomes.extend(lost_final);
    let mut m = aggregate(
        pres.per_replica,
        outcomes,
        cfg,
        n_shed,
        pres.n_rebalanced,
        stats,
    );
    // availability over the run: crash downtime clipped to the makespan,
    // summed across replicas, against n_rep replica-seconds
    let span = m.makespan_s;
    let mut downtime = 0.0;
    for f in &res.schedule.faults {
        if let FaultKind::Crash { recovery_s } = f.kind {
            downtime += ((f.t_s + recovery_s.max(0.0)).min(span) - f.t_s.min(span)).max(0.0);
        }
    }
    m.faults.n_faults = res.schedule.faults.len();
    m.faults.downtime_s = downtime;
    m.faults.mean_recovery_s = if m.faults.n_crashes > 0 {
        downtime / m.faults.n_crashes as f64
    } else {
        0.0
    };
    m.faults.availability = if span > 1e-12 {
        (1.0 - downtime / (n_rep as f64 * span)).max(0.0)
    } else {
        1.0
    };
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ChipletClass, Dataflow};
    use crate::sim::coster::MappingPolicy;
    use crate::sim::metrics::SloSpec;
    use crate::sim::simulate_fleet;
    use crate::workload::serving::ServingStrategy;
    use crate::workload::trace::TraceSpec;

    fn tiny_hw() -> HwConfig {
        HwConfig::homogeneous(
            2,
            2,
            ChipletClass::S,
            Dataflow::WeightStationary,
            32.0,
            16.0,
        )
    }

    fn tiny_spec() -> TraceSpec {
        TraceSpec {
            mean_in: 48.0,
            mean_out: 8.0,
            sigma_in: 0.5,
            sigma_out: 0.4,
            max_len: 4096,
            shared_prefix_tokens: 0,
        }
    }

    fn tiny_cfg() -> SimConfig {
        let mut cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
        cfg.policy = MappingPolicy::Pipeline;
        cfg.max_batch = 6;
        cfg.chunk_tokens = 24;
        cfg.kv_budget_tokens = 1024;
        cfg.ctx_bucket = 32;
        cfg.eval_blocks = 1;
        cfg.slo = SloSpec::new(0.5, 0.1);
        cfg
    }

    fn tiny_setup(rate_scale: f64, n: usize, seed: u64) -> (RequestStream, SimProbe) {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let cfg = tiny_cfg();
        let probe = crate::sim::probe(&model, &hw, &cfg, &tiny_spec());
        (
            RequestStream::poisson(&tiny_spec(), rate_scale * probe.capacity_rps(), n, seed),
            probe,
        )
    }

    #[test]
    fn legacy_routers_route_like_the_old_match_arms() {
        let obs = |backlog: u64| ReplicaObs {
            clock_s: 0.0,
            busy_s: 0.0,
            queue_depth: 0,
            backlog_tokens: backlog,
            pending_prefill_tokens: 0,
            kv_free_tokens: 100,
            n_prefilling: 0,
            n_decoding: 0,
            down: false,
        };
        let reps = [obs(5), obs(2), obs(2), obs(9)];
        let req = TimedRequest {
            id: 0,
            arrival_s: 0.0,
            input_len: 8,
            output_len: 4,
        };
        let mut rr = RoundRobinRouter::default();
        assert_eq!(
            (0..6).map(|_| rr.route(&req, &reps)).collect::<Vec<_>>(),
            vec![0, 1, 2, 3, 0, 1]
        );
        // JSQ: fewest backlog tokens, ties to the lowest index
        assert_eq!(JsqRouter.route(&req, &reps), 1);
        // KV-aware: skips replicas without footprint headroom
        let mut tight = reps;
        tight[1].kv_free_tokens = 4;
        assert_eq!(KvAwareRouter.route(&req, &tight), 2);
        // ... and falls back to JSQ when nothing has headroom
        let dry = [obs(5), obs(2)].map(|mut o| {
            o.kv_free_tokens = 0;
            o
        });
        assert_eq!(KvAwareRouter.route(&req, &dry), 1);
    }

    #[test]
    fn infinite_margin_and_threshold_are_bitwise_baseline() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let cfg = tiny_cfg();
        let (stream, probe) = tiny_setup(2.0, 14, 11);
        for fleet in [
            FleetConfig::homogeneous(3, RouterPolicy::JoinShortestQueue),
            FleetConfig::disaggregated(1, 2, 1e-7),
        ] {
            let base = simulate_fleet(&stream, &model, &hw, &cfg, &fleet);
            let hws = vec![hw.clone(); fleet.total_replicas()];
            let never_shed = Frontend::with_shedding(probe, f64::INFINITY)
                .with_rebalance(RebalanceSpec::new(f64::INFINITY, 0.0));
            let m = simulate_fleet_frontend(&stream, &model, &hws, &cfg, &fleet, &never_shed);
            assert_eq!(m.n_shed, 0);
            assert_eq!(m.n_rebalanced, 0);
            assert_eq!(m.makespan_s.to_bits(), base.makespan_s.to_bits());
            assert_eq!(m.energy_pj.to_bits(), base.energy_pj.to_bits());
            assert_eq!(m.ttft.p99.to_bits(), base.ttft.p99.to_bits());
            assert_eq!(m.tpot.p99.to_bits(), base.tpot.p99.to_bits());
            assert_eq!(m.outcomes.len(), base.outcomes.len());
            for (a, b) in m.outcomes.iter().zip(&base.outcomes) {
                assert_eq!(a.arrival_s.to_bits(), b.arrival_s.to_bits());
                assert_eq!(a.first_token_s.map(f64::to_bits), b.first_token_s.map(f64::to_bits));
                assert_eq!(a.finish_s.map(f64::to_bits), b.finish_s.map(f64::to_bits));
            }
        }
    }

    #[test]
    fn zero_margin_sheds_everything_and_conserves() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let cfg = tiny_cfg();
        let (stream, probe) = tiny_setup(1.5, 10, 3);
        let fleet = FleetConfig::homogeneous(2, RouterPolicy::JoinShortestQueue);
        let hws = vec![hw.clone(); 2];
        let fe = Frontend::with_shedding(probe, 0.0);
        let m = simulate_fleet_frontend(&stream, &model, &hws, &cfg, &fleet, &fe);
        assert_eq!(m.n_arrived, stream.requests.len());
        assert_eq!(m.n_shed, m.n_arrived);
        assert_eq!(m.n_rejected, m.n_arrived);
        assert_eq!(m.n_completed, 0);
        assert!((m.shed_rate - 1.0).abs() < 1e-12);
    }

    #[test]
    fn moderate_shedding_keeps_conservation_and_reports_rate() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let mut cfg = tiny_cfg();
        let (stream, probe) = tiny_setup(3.0, 18, 5);
        cfg.slo = probe.slo(3.0, 4.0);
        for fleet in [
            FleetConfig::homogeneous(2, RouterPolicy::JoinShortestQueue),
            FleetConfig::disaggregated(1, 1, 1e-7),
        ] {
            let hws = vec![hw.clone(); fleet.total_replicas()];
            let fe = Frontend::with_shedding(probe, 1.0);
            let m = simulate_fleet_frontend(&stream, &model, &hws, &cfg, &fleet, &fe);
            assert_eq!(
                m.n_completed + m.n_rejected,
                m.n_arrived,
                "{}",
                fleet.describe()
            );
            assert!(m.n_shed <= m.n_rejected);
            assert!((m.shed_rate - m.n_shed as f64 / m.n_arrived as f64).abs() < 1e-12);
            // shedding is deterministic too
            let b = simulate_fleet_frontend(&stream, &model, &hws, &cfg, &fleet, &fe);
            assert_eq!(m.makespan_s.to_bits(), b.makespan_s.to_bits());
            assert_eq!(m.n_shed, b.n_shed);
        }
    }

    /// `RouterPolicy::KvAware` runs end-to-end: with ample KV headroom
    /// it is bitwise JSQ (every replica always has room, so the filter
    /// never bites), and under a tight budget it still conserves.
    #[test]
    fn kv_aware_policy_runs_and_matches_jsq_when_kv_ample() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let mut cfg = tiny_cfg();
        let (stream, _) = tiny_setup(2.0, 14, 19);
        cfg.kv_budget_tokens = 1 << 20; // never binding
        let jsq = simulate_fleet(
            &stream,
            &model,
            &hw,
            &cfg,
            &FleetConfig::homogeneous(3, RouterPolicy::JoinShortestQueue),
        );
        let kva = simulate_fleet(
            &stream,
            &model,
            &hw,
            &cfg,
            &FleetConfig::homogeneous(3, RouterPolicy::KvAware),
        );
        assert_eq!(kva.makespan_s.to_bits(), jsq.makespan_s.to_bits());
        assert_eq!(kva.energy_pj.to_bits(), jsq.energy_pj.to_bits());
        // tight budget: the headroom filter may reroute, but the run
        // still conserves and stays deterministic
        cfg.kv_budget_tokens = 160;
        let tight = simulate_fleet(
            &stream,
            &model,
            &hw,
            &cfg,
            &FleetConfig::homogeneous(3, RouterPolicy::KvAware),
        );
        assert_eq!(tight.n_completed + tight.n_rejected, tight.n_arrived);
        let again = simulate_fleet(
            &stream,
            &model,
            &hw,
            &cfg,
            &FleetConfig::homogeneous(3, RouterPolicy::KvAware),
        );
        assert_eq!(tight.makespan_s.to_bits(), again.makespan_s.to_bits());
    }

    /// Heterogeneous per-replica hardware: a fleet whose second replica
    /// is larger must not be slower than the same fleet with two small
    /// replicas, and per-hw cost memos keep runs deterministic.
    #[test]
    fn heterogeneous_replicas_run_and_conserve() {
        let model = ModelSpec::tiny();
        let small = tiny_hw();
        let big = HwConfig::homogeneous(
            2,
            4,
            ChipletClass::S,
            Dataflow::WeightStationary,
            32.0,
            16.0,
        );
        let cfg = tiny_cfg();
        let (stream, _) = tiny_setup(2.5, 12, 7);
        let fleet = FleetConfig::homogeneous(2, RouterPolicy::JoinShortestQueue);
        let hws = vec![small.clone(), big];
        let m = simulate_fleet_frontend(&stream, &model, &hws, &cfg, &fleet, &Frontend::baseline());
        assert_eq!(m.n_completed + m.n_rejected, m.n_arrived);
        assert_eq!(m.per_replica.len(), 2);
        let b = simulate_fleet_frontend(&stream, &model, &hws, &cfg, &fleet, &Frontend::baseline());
        assert_eq!(m.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(m.energy_pj.to_bits(), b.energy_pj.to_bits());
    }
}

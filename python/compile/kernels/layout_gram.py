"""L1 Pallas kernel: chiplet-layout Gram matrix (paper Eq. 3).

    K[q, n] = sigma2 * sum_t  a[q, :, t]^T  W  b[n, :, t]

TPU mapping (see DESIGN.md #Hardware-Adaptation): the kernel is a pair of
MXU-friendly contractions per (q, n) block --

    bw[n, u, t] = sum_v W[u, v] * b[n, v, t]        (S x S @ S x T dots)
    K[q, n]     = sum_{u,t} a[q, u, t] * bw[n, u, t]

with BlockSpec keeping the (S, S) Manhattan weight matrix W resident in
VMEM across the whole candidate grid while q/n tiles stream from HBM.
interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO with identical
numerics (the structure -- blocking, dot shapes -- is what carries to TPU).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _layout_gram_kernel(a_ref, b_ref, w_ref, o_ref, *, sigma2):
    a = a_ref[...]  # (bq, S, T)
    b = b_ref[...]  # (bn, S, T)
    w = w_ref[...]  # (S, S)
    # bw[n,u,t] = sum_v w[u,v] b[n,v,t]  -- one (S,S)x(S,T) dot per n row,
    # expressed as a single dot_general so the MXU sees full tiles.
    bw = jax.lax.dot_general(
        b, w, dimension_numbers=(((1,), (1,)), ((), ()))
    )  # (bn, T, S) contracted over v
    bw = jnp.transpose(bw, (0, 2, 1))  # (bn, S, T)
    # K[q,n] = sum_{u,t} a[q,u,t] bw[n,u,t] -- flattened (u,t) matmul.
    af = a.reshape(a.shape[0], -1)
    bwf = bw.reshape(bw.shape[0], -1)
    o_ref[...] = sigma2 * (af @ bwf.T)


def layout_gram(a, b, w, sigma2=1.0, block_q=None, block_n=None):
    """Pallas layout-Gram. a: (Q,S,T), b: (N,S,T), w: (S,S) -> (Q,N)."""
    q, s, t = a.shape
    n = b.shape[0]
    bq = min(block_q or q, q)
    bn = min(block_n or n, n)
    assert q % bq == 0 and n % bn == 0, "block sizes must tile Q/N"
    grid = (q // bq, n // bn)
    return pl.pallas_call(
        functools.partial(_layout_gram_kernel, sigma2=float(sigma2)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, s, t), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((bn, s, t), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((s, s), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, n), a.dtype),
        interpret=True,
    )(a, b, w)


def _layout_gram_diag_kernel(a_ref, w_ref, o_ref, *, sigma2):
    a = a_ref[...]  # (bq, S, T)
    w = w_ref[...]  # (S, S)
    aw = jax.lax.dot_general(
        a, w, dimension_numbers=(((1,), (1,)), ((), ()))
    )  # (bq, T, S)
    aw = jnp.transpose(aw, (0, 2, 1))
    o_ref[...] = sigma2 * jnp.sum(a * aw, axis=(1, 2))


def layout_gram_diag(a, w, sigma2=1.0, block_q=None):
    """diag(layout_gram(a, a, w)) without forming the full Gram. -> (Q,)."""
    q, s, t = a.shape
    bq = min(block_q or q, q)
    assert q % bq == 0
    return pl.pallas_call(
        functools.partial(_layout_gram_diag_kernel, sigma2=float(sigma2)),
        grid=(q // bq,),
        in_specs=[
            pl.BlockSpec((bq, s, t), lambda i: (i, 0, 0)),
            pl.BlockSpec((s, s), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((q,), a.dtype),
        interpret=True,
    )(a, w)

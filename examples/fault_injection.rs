//! Fault injection and failure-aware serving: replica crashes,
//! straggler windows and degraded links replayed against the fleet
//! front end, with health-aware failover, capped-exponential retry
//! and proactive pre-crash draining (the robustness counterpart of
//! `frontend_control`).
//!
//! The default configuration replays GovReport-style traffic across a
//! 4-replica fleet carved from a 512-TOPS budget, injects a seeded
//! crash + straggler schedule, and walks the resilience ladder:
//! failover off (JSQ keeps routing into the crashed replica's empty
//! queue), failover, +retry, +drain, +one spare replica. It then
//! checks:
//!
//! * the zero-fault anchor: with an empty schedule and retry disabled,
//!   the fault layer is bit-identical to `simulate_fleet_frontend` —
//!   the subsystem is free when unused;
//! * every cell conserves requests (completed + rejected == arrived),
//!   losses stay within rejections, and the schedule's crashes are
//!   replayed exactly;
//! * the study rerun is bit-identical for the fixed seeds;
//! * `dse::search_resilience` sweeps spare x retry x drain and returns
//!   a deterministic per-replica-goodput winner;
//! * at the overload rate, failover+retry+drain beats the
//!   failover-disabled baseline on SLO goodput AND loses strictly
//!   fewer requests on the same seeded schedule (full run only — the
//!   tiny CI smoke just proves the subsystem end-to-end).
//!
//! Run:   cargo run --release --example fault_injection
//! CI:    cargo run --example fault_injection -- --tiny
//!
//! Output is deterministic for the fixed seeds baked in below.

use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::experiments as exp;
use compass::sim::{self, Frontend, ResilienceSpec, RouterPolicy, SimConfig};
use compass::workload::serving::ServingStrategy;
use compass::workload::ModelSpec;

const SEED: u64 = 29;

struct Setup {
    label: &'static str,
    scene: exp::FleetScene,
    model: ModelSpec,
    hw: HwConfig,
    cfg: SimConfig,
}

fn setup(tiny: bool) -> Setup {
    if tiny {
        let mut cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
        cfg.max_batch = 8;
        cfg.chunk_tokens = 32;
        cfg.kv_budget_tokens = 2048;
        cfg.ctx_bucket = 64;
        cfg.eval_blocks = 1;
        let mut scene = exp::FleetScene::new("sharegpt", 64.0, 2, 12);
        scene.rates_rps = Vec::new(); // auto {0.8, 1.3} x capacity
        Setup {
            label: "tiny-faults",
            scene,
            model: ModelSpec::tiny(),
            hw: HwConfig::homogeneous(
                2,
                2,
                ChipletClass::S,
                Dataflow::WeightStationary,
                32.0,
                16.0,
            ),
            cfg,
        }
    } else {
        let mut cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
        cfg.ctx_bucket = 1024; // GovReport contexts are ~10k tokens
        let scene = exp::FleetScene::new("govreport", 512.0, 4, 36);
        Setup {
            label: "govreport-512T-faults4",
            model: scene.model(),
            hw: exp::sim_default_hw(scene.tops_per_replica()),
            scene,
            cfg,
        }
    }
}

fn main() {
    let tiny = std::env::args().skip(1).any(|a| a == "--tiny");
    let s = setup(tiny);
    let t0 = std::time::Instant::now();
    let knobs = exp::FaultKnobs::default();

    println!(
        "fault_injection [{}] model={} | {} replicas of: {}",
        s.label,
        s.model.name,
        s.scene.n_replicas,
        s.hw.describe()
    );

    // --- zero-fault anchor: empty schedule == plain front end ---
    {
        let spec = s.scene.spec();
        let probe = sim::probe(&s.model, &s.hw, &s.cfg, &spec);
        let stream = sim::RequestStream::poisson(
            &spec,
            1.2 * s.scene.n_replicas as f64 * probe.capacity_rps(),
            s.scene.n_requests,
            SEED,
        );
        let mut cfg = s.cfg;
        cfg.slo = probe.slo(3.0, 4.0);
        let fleet =
            sim::FleetConfig::homogeneous(s.scene.n_replicas, RouterPolicy::JoinShortestQueue);
        let hws = vec![s.hw.clone(); fleet.total_replicas()];
        let plain = sim::simulate_fleet_frontend(
            &stream,
            &s.model,
            &hws,
            &cfg,
            &fleet,
            &Frontend::baseline(),
        );
        let faultless = sim::simulate_fleet_faults(
            &stream,
            &s.model,
            &hws,
            &cfg,
            &fleet,
            &Frontend::baseline(),
            &ResilienceSpec::none(),
        );
        assert_eq!(
            plain.makespan_s.to_bits(),
            faultless.makespan_s.to_bits(),
            "fault layer drifted from the plain front end with no faults"
        );
        assert_eq!(plain.energy_pj.to_bits(), faultless.energy_pj.to_bits());
        assert_eq!(plain.ttft.p99.to_bits(), faultless.ttft.p99.to_bits());
        assert_eq!(plain.n_completed, faultless.n_completed);
        assert_eq!(faultless.faults.n_failed, 0);
        assert_eq!(faultless.faults.availability.to_bits(), 1.0f64.to_bits());
        println!("zero-fault anchor: fault layer is bit-identical when disabled: PASS");
    }

    // --- the resilience ladder ---
    let rows = exp::fault_study_with_model(&s.scene, &s.model, &s.hw, &s.cfg, &knobs, SEED);
    exp::fault_study_table(&s.scene, &rows).print();
    for r in &rows {
        let m = &r.metrics;
        assert_eq!(
            m.n_completed + m.n_rejected,
            m.n_arrived,
            "{} @ {} does not conserve requests",
            r.key,
            r.rate_rps
        );
        assert!(!m.truncated, "{} @ {} truncated", r.key, r.rate_rps);
        assert!(
            m.faults.n_lost <= m.n_rejected,
            "{}: losses beyond rejections",
            r.key
        );
        if r.key == "no-fault" {
            assert_eq!(m.faults.n_failed, 0, "faults fired in the fault-free cell");
        } else {
            assert_eq!(
                m.faults.n_crashes, knobs.n_crashes,
                "{}: schedule not replayed exactly",
                r.key
            );
        }
    }
    println!("\nconservation: every cell completes or rejects every arrival: PASS");

    // --- determinism: study rerun is bit-identical ---
    {
        let a = exp::fault_study_with_model(&s.scene, &s.model, &s.hw, &s.cfg, &knobs, SEED);
        let pick = |rows: &[exp::FaultStudyRow]| {
            rows.iter()
                .map(|r| {
                    (
                        r.metrics.makespan_s.to_bits(),
                        r.metrics.faults.n_failed,
                        r.metrics.faults.n_lost,
                    )
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(pick(&rows), pick(&a), "fault study rerun differs");
        println!("determinism: full study rerun is bit-identical: PASS");
    }

    // --- headline orderings at overload ---
    print!("\n{}", exp::fault_study_headline(&rows));
    let hi = rows
        .iter()
        .map(|r| r.rate_rps)
        .fold(f64::NEG_INFINITY, f64::max);
    let at = |key: &str| {
        rows.iter()
            .find(|r| r.rate_rps == hi && r.key == key)
            .map(|r| &r.metrics)
            .expect("cell present")
    };
    let (blind, full) = (at("fault"), at("fault+failover+retry+drain"));
    let resilient_ok = full.slo_goodput_tps > blind.slo_goodput_tps
        && full.faults.n_lost < blind.faults.n_lost;
    println!(
        "failover+retry+drain > failover-off on goodput and losses at overload: {}",
        if resilient_ok { "PASS" } else { "FAIL" }
    );

    // --- resilience provisioning search (spare x retry x drain) ---
    {
        let spec = s.scene.spec();
        let probe = sim::probe(&s.model, &s.hw, &s.cfg, &spec);
        let mut cfg = s.cfg;
        cfg.slo = probe.slo(3.0, 4.0);
        let stream = sim::RequestStream::poisson(
            &spec,
            0.9 * s.scene.n_replicas as f64 * probe.capacity_rps(),
            s.scene.n_requests,
            SEED,
        );
        let schedule = sim::FaultSchedule::seeded(
            s.scene.n_replicas,
            stream.horizon_s(),
            knobs.n_crashes,
            knobs.n_stragglers,
            knobs.fault_seed,
        );
        let space = compass::dse::ResilienceSpace::new(s.scene.n_replicas);
        let (best, scored) = compass::dse::search_resilience(
            &stream,
            &s.model,
            &s.hw,
            &cfg,
            &Frontend::baseline(),
            &space,
            &schedule,
        );
        let (best2, _) = compass::dse::search_resilience(
            &stream,
            &s.model,
            &s.hw,
            &cfg,
            &Frontend::baseline(),
            &space,
            &schedule,
        );
        assert_eq!(best, best2, "resilience search not deterministic");
        println!(
            "\nresilience search over {} candidates under '{}': best = {}",
            scored.len(),
            schedule.describe(),
            best.describe()
        );
    }

    // the full GovReport run is the acceptance gate for the resilience
    // ordering; the tiny smoke only proves the subsystem end-to-end
    // (at toy scale a single crash can be invisible at the low rate)
    if !tiny && !resilient_ok {
        eprintln!(
            "[fault_injection] FAIL: failover+retry+drain did not beat the \
             failover-disabled baseline at overload"
        );
        std::process::exit(1);
    }
    eprintln!("[fault_injection] done in {:.1}s", t0.elapsed().as_secs_f64());
}

//! Scenario definitions for the paper's evaluation matrix (§VI-A):
//! {ShareGPT, GovReport} x {prefill, decode} x {64, 512, 2048} TOPS.

use crate::arch::HwSpace;
use crate::workload::serving::Scenario;
use crate::workload::trace::{Trace, TraceSpec};
use crate::workload::ModelSpec;

/// Model matched to a compute target (paper: parameter scale aligned
/// with compute capacity).
pub fn model_for_tops(tops: f64) -> ModelSpec {
    if tops <= 64.0 {
        ModelSpec::gpt3_7b()
    } else if tops <= 512.0 {
        ModelSpec::gpt3_13b()
    } else {
        ModelSpec::llama3_70b()
    }
}

/// One cell of the paper's scenario matrix.
#[derive(Debug, Clone)]
pub struct Scene {
    pub trace_name: String,
    pub prefill: bool,
    pub tops: f64,
    /// Batches sampled from the trace for distribution-aware DSE.
    pub n_batches: usize,
    /// Requests per batch (paper: prefill 4, decode 128).
    pub batch_size: usize,
}

impl Scene {
    pub fn new(trace_name: &str, prefill: bool, tops: f64) -> Self {
        Scene {
            trace_name: trace_name.to_string(),
            prefill,
            tops,
            n_batches: 2,
            batch_size: if prefill { 4 } else { 128 },
        }
    }

    /// The full 12-scene matrix of Fig. 7 / Table VI.
    pub fn paper_matrix() -> Vec<Scene> {
        let mut out = Vec::new();
        for trace in ["sharegpt", "govreport"] {
            for prefill in [true, false] {
                for tops in [64.0, 512.0, 2048.0] {
                    out.push(Scene::new(trace, prefill, tops));
                }
            }
        }
        out
    }

    /// A reduced 4-scene matrix for CI-budget benches.
    pub fn reduced_matrix() -> Vec<Scene> {
        vec![
            Scene::new("sharegpt", true, 64.0),
            Scene::new("sharegpt", false, 64.0),
            Scene::new("govreport", true, 512.0),
            Scene::new("govreport", false, 512.0),
        ]
    }

    pub fn label(&self) -> String {
        format!(
            "{}-{}-{}T",
            self.trace_name,
            if self.prefill { "prefill" } else { "decode" },
            self.tops as u64
        )
    }

    pub fn space(&self) -> HwSpace {
        HwSpace::paper(self.tops)
    }

    /// Build (fitting scenario, test scenario, fitting trace, model):
    /// the fitting set guides DSE, the disjoint test set validates
    /// (paper §VI-A scenario setup).
    pub fn build(&self, seed: u64) -> (Scenario, Scenario, Trace, ModelSpec) {
        let spec = TraceSpec::by_name(&self.trace_name).expect("known trace");
        let fit = Trace::new(&spec, 512, seed);
        let test = Trace::new(&spec, 512, seed.wrapping_add(0x9e37_79b9));
        let mk = |t: &Trace| {
            if self.prefill {
                Scenario::prefill(t, self.batch_size, self.n_batches)
            } else {
                Scenario::decode(t, self.batch_size, self.n_batches)
            }
        };
        (mk(&fit), mk(&test), fit, model_for_tops(self.tops))
    }
}

/// A serving-simulator scene: a trace distribution plus a compute
/// target, swept over arrival rates by the sim study (the time-domain
/// counterpart of [`Scene`]).
#[derive(Debug, Clone)]
pub struct SimScene {
    pub trace_name: String,
    pub tops: f64,
    /// Requests per simulated stream.
    pub n_requests: usize,
    /// Arrival rates to sweep (req/s); empty = auto-calibrated
    /// {0.4, 0.8, 1.3} x estimated capacity.
    pub rates_rps: Vec<f64>,
}

impl SimScene {
    pub fn new(trace_name: &str, tops: f64, n_requests: usize) -> Self {
        SimScene {
            trace_name: trace_name.to_string(),
            tops,
            n_requests,
            rates_rps: Vec::new(),
        }
    }

    /// The paper-§VI-F-flavoured default: mixed GovReport traffic
    /// (long prompts, decode-heavy token mix) at 512 TOPS.
    pub fn govreport_512() -> Self {
        SimScene::new("govreport", 512.0, 24)
    }

    pub fn label(&self) -> String {
        format!("{}-serve-{}T", self.trace_name, self.tops as u64)
    }

    pub fn spec(&self) -> TraceSpec {
        TraceSpec::by_name(&self.trace_name).expect("known trace")
    }

    pub fn model(&self) -> ModelSpec {
        model_for_tops(self.tops)
    }

    /// A Poisson request stream at `rate_rps` for this scene.
    pub fn stream(&self, rate_rps: f64, seed: u64) -> crate::sim::RequestStream {
        crate::sim::RequestStream::poisson(&self.spec(), rate_rps, self.n_requests, seed)
    }
}

/// A fleet-serving scene: a trace distribution plus a *total* compute
/// budget split evenly across the replicas of every fleet shape under
/// study (the scale-out counterpart of [`SimScene`]).
#[derive(Debug, Clone)]
pub struct FleetScene {
    pub trace_name: String,
    /// Total compute budget across the fleet (TOPS).
    pub total_tops: f64,
    /// Replicas per fleet shape (disaggregated splits partition it).
    pub n_replicas: usize,
    /// Requests per simulated stream.
    pub n_requests: usize,
    /// Arrival rates to sweep (req/s); empty = auto-calibrated
    /// {0.4, 0.8, 1.3} x (n_replicas x per-replica capacity).
    pub rates_rps: Vec<f64>,
}

impl FleetScene {
    /// `n_replicas` is clamped to >= 2 — the study's comparison set
    /// (`default_fleet_shapes`) needs at least two replicas, and the
    /// clamp must happen here so per-replica sizing
    /// (`tops_per_replica`) and the auto rate sweep stay in lockstep
    /// with the fleets actually simulated.
    pub fn new(trace_name: &str, total_tops: f64, n_replicas: usize, n_requests: usize) -> Self {
        FleetScene {
            trace_name: trace_name.to_string(),
            total_tops,
            n_replicas: n_replicas.max(2),
            n_requests,
            rates_rps: Vec::new(),
        }
    }

    /// Default study: GovReport traffic on 4 x 128-TOPS replicas.
    pub fn govreport_512x4() -> Self {
        FleetScene::new("govreport", 512.0, 4, 32)
    }

    pub fn label(&self) -> String {
        format!(
            "{}-fleet{}x{}T",
            self.trace_name,
            self.n_replicas,
            self.tops_per_replica() as u64
        )
    }

    pub fn tops_per_replica(&self) -> f64 {
        self.total_tops / self.n_replicas as f64
    }

    pub fn spec(&self) -> TraceSpec {
        TraceSpec::by_name(&self.trace_name).expect("known trace")
    }

    /// Model matched to the *total* budget: the fleet serves one model,
    /// however many packages it spans.
    pub fn model(&self) -> ModelSpec {
        model_for_tops(self.total_tops)
    }

    /// A Poisson request stream at `rate_rps` for this scene.
    pub fn stream(&self, rate_rps: f64, seed: u64) -> crate::sim::RequestStream {
        crate::sim::RequestStream::poisson(&self.spec(), rate_rps, self.n_requests, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_matrix_has_12_scenes() {
        let m = Scene::paper_matrix();
        assert_eq!(m.len(), 12);
        let labels: std::collections::HashSet<String> = m.iter().map(|s| s.label()).collect();
        assert_eq!(labels.len(), 12);
    }

    #[test]
    fn model_matching_follows_paper() {
        assert_eq!(model_for_tops(64.0).name, "GPT3-7B");
        assert_eq!(model_for_tops(512.0).name, "GPT3-13B");
        assert_eq!(model_for_tops(2048.0).name, "LLaMA3-70B");
    }

    #[test]
    fn build_produces_disjoint_fit_test() {
        let s = Scene::new("sharegpt", true, 64.0);
        let (fit, test, trace, model) = s.build(3);
        assert_eq!(model.name, "GPT3-7B");
        assert_eq!(fit.groups.len(), 2);
        assert_eq!(test.groups.len(), 2);
        assert_ne!(
            format!("{:?}", fit.groups[0].batch),
            format!("{:?}", test.groups[0].batch)
        );
        assert!(trace.mean_in() > 1.0);
    }

    #[test]
    fn batch_sizes_follow_paper_defaults() {
        assert_eq!(Scene::new("sharegpt", true, 64.0).batch_size, 4);
        assert_eq!(Scene::new("sharegpt", false, 64.0).batch_size, 128);
    }

    #[test]
    fn fleet_scene_splits_the_budget() {
        let s = FleetScene::govreport_512x4();
        assert_eq!(s.label(), "govreport-fleet4x128T");
        assert_eq!(s.tops_per_replica(), 128.0);
        assert_eq!(s.model().name, "GPT3-13B");
        let stream = s.stream(2.0, 7);
        assert_eq!(stream.len(), s.n_requests);
        assert_eq!(stream.requests, s.stream(2.0, 7).requests);
    }

    #[test]
    fn sim_scene_builds_streams() {
        let s = SimScene::govreport_512();
        assert_eq!(s.label(), "govreport-serve-512T");
        assert_eq!(s.model().name, "GPT3-13B");
        let stream = s.stream(2.0, 7);
        assert_eq!(stream.len(), s.n_requests);
        assert_eq!(stream.requests, s.stream(2.0, 7).requests);
    }
}

//! Integration tests for the batched, multi-threaded evaluation engine:
//! thread-count invariance of GA results under a fixed seed, equivalence
//! with the serial closure path, and memo-cache consistency against the
//! one-shot reference evaluator.

use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::cost::engine::{BatchEvaluator, MappingEvaluator};
use compass::cost::Evaluator;
use compass::ga::{self, ops, GaConfig};
use compass::mapping::Mapping;
use compass::util::Rng;
use compass::workload::{build_workload, ModelSpec, Request, Workload, WorkloadParams};

fn setup() -> (Workload, HwConfig) {
    let model = ModelSpec::tiny();
    let batch: Vec<Request> = (0..6)
        .map(|i| {
            if i % 2 == 0 {
                Request::prefill(32 + 16 * i as u64)
            } else {
                Request::decode(200 + 50 * i as u64)
            }
        })
        .collect();
    let w = build_workload(
        &model,
        &batch,
        &WorkloadParams {
            micro_batch_size: 2,
            tensor_parallel: 2,
            eval_blocks: 2,
        },
    );
    let hw = HwConfig::homogeneous(
        2,
        2,
        ChipletClass::S,
        Dataflow::WeightStationary,
        32.0,
        16.0,
    );
    (w, hw)
}

/// Same seed, 1 thread vs N threads: bit-identical GA outcome.
#[test]
fn ga_results_identical_across_thread_counts() {
    let (w, hw) = setup();
    let rows = w.num_micro_batches();
    let cols = w.layers_per_mb;
    let cfg = GaConfig::tiny();
    let serial = ga::search(
        rows,
        cols,
        4,
        &cfg,
        &MappingEvaluator::new(&w, &hw).with_threads(1),
    );
    let parallel = ga::search(
        rows,
        cols,
        4,
        &cfg,
        &MappingEvaluator::new(&w, &hw).with_threads(4),
    );
    assert_eq!(serial.best, parallel.best);
    assert_eq!(
        serial.best_fitness.to_bits(),
        parallel.best_fitness.to_bits()
    );
    assert_eq!(serial.evaluations, parallel.evaluations);
    assert_eq!(serial.history.len(), parallel.history.len());
    for (a, b) in serial.history.iter().zip(&parallel.history) {
        assert_eq!(a.best.to_bits(), b.best.to_bits());
        assert_eq!(a.mean.to_bits(), b.mean.to_bits());
    }
}

/// The engine-backed GA reproduces the seed's serial-closure GA exactly:
/// the batch refactor changed scheduling of evaluations, not results.
#[test]
fn engine_ga_matches_serial_closure_ga() {
    let (w, hw) = setup();
    let rows = w.num_micro_batches();
    let cols = w.layers_per_mb;
    let cfg = GaConfig::tiny();
    let ev = Evaluator::new();
    let closure = ga::search(rows, cols, 4, &cfg, &|m: &Mapping| {
        let r = ev.eval_batch(&w, &hw, m);
        r.latency_cycles * r.energy_pj
    });
    let engine = ga::search(rows, cols, 4, &cfg, &MappingEvaluator::new(&w, &hw));
    assert_eq!(closure.best, engine.best);
    assert_eq!(closure.best_fitness.to_bits(), engine.best_fitness.to_bits());
}

/// Property sweep: memoised fitness (first call, cached call, and batch
/// path) equals a fresh `Evaluator::eval_batch` for random mappings.
#[test]
fn memoised_fitness_equals_fresh_eval_batch() {
    let (w, hw) = setup();
    let ev = Evaluator::new();
    let mev = MappingEvaluator::new(&w, &hw).with_threads(3);
    let mut rng = Rng::seed_from_u64(42);
    let mut maps = Vec::new();
    for _ in 0..40 {
        maps.push(ops::random_mapping(
            w.num_micro_batches(),
            w.layers_per_mb,
            4,
            &mut rng,
        ));
    }
    let mut batch_fits = Vec::new();
    mev.eval_batch(&maps, &mut batch_fits);
    for (i, m) in maps.iter().enumerate() {
        let r = ev.eval_batch(&w, &hw, m);
        let reference = r.latency_cycles * r.energy_pj;
        assert!(reference.is_finite() && reference > 0.0, "case {i}");
        let first = mev.fitness(m);
        let cached = mev.fitness(m);
        assert_eq!(first.to_bits(), reference.to_bits(), "case {i}");
        assert_eq!(cached.to_bits(), reference.to_bits(), "case {i}");
        assert_eq!(batch_fits[i].to_bits(), reference.to_bits(), "case {i}");
    }
}

/// Duplicate-heavy batches (elites + crossover clones) are only ever
/// simulated once per distinct genome.
#[test]
fn batch_dedup_simulates_each_genome_once() {
    let (w, hw) = setup();
    let mev = MappingEvaluator::new(&w, &hw).with_threads(2);
    let mut rng = Rng::seed_from_u64(5);
    let distinct: Vec<Mapping> = (0..3)
        .map(|_| ops::random_mapping(w.num_micro_batches(), w.layers_per_mb, 4, &mut rng))
        .collect();
    let mut batch = Vec::new();
    for i in 0..12 {
        batch.push(distinct[i % 3].clone());
    }
    let mut fits = Vec::new();
    mev.eval_batch(&batch, &mut fits);
    assert_eq!(mev.cache_len(), 3);
    for i in 0..12 {
        assert_eq!(fits[i].to_bits(), fits[i % 3].to_bits());
    }
}

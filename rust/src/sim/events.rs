//! Deterministic binary-heap event queue for the fleet front end.
//!
//! The front-end layers used to keep their time-ordered work (pending
//! KV migrations, retry backoffs, fault schedules, disaggregated
//! handoffs) in sorted `Vec`s maintained with `partition_point` inserts
//! or re-`sort_by` passes — O(n) per insert on the per-event hot path.
//! [`EventHeap`] replaces them with a binary heap under an explicit
//! total order, so pushes and pops are O(log n) while draining the
//! exact same deterministic sequence:
//!
//! * primary key: time `t` (ascending, `f64::total_cmp`);
//! * secondary key: caller `id` (ascending) — the request id for
//!   migrations/retries, a constant for schedules ordered by time only;
//! * final tie-break: insertion sequence (FIFO among exact `(t, id)`
//!   ties), matching the insert-after-equals `partition_point`
//!   convention (and `sort_by`'s stability) of the sorted-`Vec` code it
//!   replaces.
//!
//! The payload is deliberately *not* part of the order, so `T` needs no
//! `Ord` and a payload change can never silently reorder a drain.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Heap entry: ordered by `(t, id, seq)` only.
#[derive(Debug, Clone)]
struct Entry<T> {
    t: f64,
    id: usize,
    seq: u64,
    item: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        self.t
            .total_cmp(&other.t)
            .then(self.id.cmp(&other.id))
            .then(self.seq.cmp(&other.seq))
    }
}

/// Min-heap of `(t, id, item)` events with a deterministic total order
/// (see the module docs for the tie-break contract).
#[derive(Debug, Clone, Default)]
pub struct EventHeap<T> {
    heap: BinaryHeap<std::cmp::Reverse<Entry<T>>>,
    seq: u64,
}

impl<T> EventHeap<T> {
    pub fn new() -> Self {
        EventHeap {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Queue `item` at time `t` with tie-break key `id`. Among exact
    /// `(t, id)` ties, pushes drain in FIFO order.
    pub fn push(&mut self, t: f64, id: usize, item: T) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(std::cmp::Reverse(Entry { t, id, seq, item }));
    }

    /// Time of the next event, if any.
    pub fn peek_t(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.0.t)
    }

    /// Pop the next event unconditionally.
    pub fn pop(&mut self) -> Option<(f64, usize, T)> {
        self.heap.pop().map(|e| (e.0.t, e.0.id, e.0.item))
    }

    /// Pop the next event if it is due at or before `t` (inclusive —
    /// the `front().t <= t` drain convention of the sorted-`Vec` loops
    /// this replaces).
    pub fn pop_due(&mut self, t: f64) -> Option<(f64, usize, T)> {
        if self.peek_t()? <= t {
            self.pop()
        } else {
            None
        }
    }

    /// Remove every event matching `pred`, returned in deterministic
    /// `(t, id, seq)` drain order; the survivors keep their queue
    /// positions (heap rebuild — O(n), for rare events like crashes).
    pub fn remove_where(&mut self, mut pred: impl FnMut(f64, usize, &T) -> bool) -> Vec<(f64, usize, T)> {
        let seq = self.seq;
        let entries = std::mem::take(&mut self.heap).into_vec();
        let mut removed: Vec<Entry<T>> = Vec::new();
        let mut kept: Vec<std::cmp::Reverse<Entry<T>>> = Vec::with_capacity(entries.len());
        for e in entries {
            if pred(e.0.t, e.0.id, &e.0.item) {
                removed.push(e.0);
            } else {
                kept.push(e);
            }
        }
        removed.sort_by(|a, b| a.cmp(b));
        self.heap = BinaryHeap::from(kept);
        self.seq = seq;
        removed.into_iter().map(|e| (e.t, e.id, e.item)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Oracle: the sorted-`Vec` with insert-after-equals that the heap
    /// replaces.
    fn model_drain(events: &[(f64, usize, u32)]) -> Vec<(f64, usize, u32)> {
        let mut v: Vec<(f64, usize, u32)> = Vec::new();
        for &(t, id, x) in events {
            let pos = v.partition_point(|e| e.0 < t || (e.0 == t && e.1 <= id));
            v.insert(pos, (t, id, x));
        }
        v
    }

    fn heap_drain(events: &[(f64, usize, u32)]) -> Vec<(f64, usize, u32)> {
        let mut h = EventHeap::new();
        for &(t, id, x) in events {
            h.push(t, id, x);
        }
        let mut out = Vec::new();
        while let Some((t, id, x)) = h.pop() {
            out.push((t, id, x));
        }
        out
    }

    #[test]
    fn equal_time_drains_by_id_then_fifo() {
        let events = [
            (2.0, 7, 0),
            (2.0, 3, 1),
            (1.0, 9, 2),
            (2.0, 3, 3), // exact (t, id) duplicate: FIFO after payload 1
            (2.0, 1, 4),
        ];
        assert_eq!(
            heap_drain(&events),
            vec![(1.0, 9, 2), (2.0, 1, 4), (2.0, 3, 1), (2.0, 3, 3), (2.0, 7, 0)]
        );
    }

    #[test]
    fn pop_due_boundary_is_inclusive() {
        let mut h = EventHeap::new();
        h.push(1.5, 0, "a");
        h.push(2.0, 0, "b");
        assert_eq!(h.pop_due(1.0), None);
        assert_eq!(h.pop_due(1.5).map(|e| e.2), Some("a"));
        assert_eq!(h.pop_due(1.99), None);
        assert_eq!(h.pop_due(2.0).map(|e| e.2), Some("b"));
        assert!(h.is_empty());
    }

    #[test]
    fn matches_sorted_vec_model_on_randomized_streams() {
        // deterministic LCG; exercises heavy (t, id) collisions
        let mut state = 0x2545_f491_4f6c_dd1du64;
        let mut rng = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) as u32
        };
        for round in 0..50 {
            let n = 1 + (rng() % 64) as usize;
            let events: Vec<(f64, usize, u32)> = (0..n)
                .map(|k| {
                    let t = (rng() % 8) as f64 * 0.25;
                    let id = (rng() % 5) as usize;
                    (t, id, (round * 1000 + k) as u32)
                })
                .collect();
            assert_eq!(
                heap_drain(&events),
                model_drain(&events),
                "divergence on round {round}"
            );
        }
    }

    #[test]
    fn remove_where_returns_drain_order_and_preserves_survivors() {
        let mut h = EventHeap::new();
        h.push(3.0, 2, 10);
        h.push(1.0, 5, 20);
        h.push(3.0, 1, 30);
        h.push(2.0, 9, 40);
        h.push(3.0, 1, 50); // FIFO duplicate of (3.0, 1)
        let removed = h.remove_where(|t, _, _| t >= 3.0);
        assert_eq!(removed, vec![(3.0, 1, 30), (3.0, 1, 50), (3.0, 2, 10)]);
        assert_eq!(h.pop(), Some((1.0, 5, 20)));
        assert_eq!(h.pop(), Some((2.0, 9, 40)));
        assert_eq!(h.pop(), None);
    }

    #[test]
    fn nan_free_total_order_handles_infinities() {
        let mut h = EventHeap::new();
        h.push(f64::INFINITY, 0, "inf");
        h.push(0.0, 0, "zero");
        h.push(-0.0, 0, "negzero"); // total_cmp: -0.0 < 0.0
        assert_eq!(h.pop().map(|e| e.2), Some("negzero"));
        assert_eq!(h.pop().map(|e| e.2), Some("zero"));
        assert_eq!(h.pop_due(1e308), None, "infinity is never due at finite t");
        assert_eq!(h.pop().map(|e| e.2), Some("inf"));
    }
}

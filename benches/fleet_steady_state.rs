//! Fleet-simulator throughput bench: how many simulated seconds of
//! multi-replica traffic one wall-clock second buys, per router policy
//! (EXPERIMENTS.md "Fleet serving"). Complements `sim_steady_state`,
//! which measures one package.

use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::sim::{self, FleetConfig, RouterPolicy, SimConfig};
use compass::util::Bench;
use compass::workload::serving::ServingStrategy;
use compass::workload::trace::TraceSpec;
use compass::workload::ModelSpec;

fn main() {
    let model = ModelSpec::gpt3_7b();
    let hw = HwConfig::homogeneous(
        2,
        4,
        ChipletClass::M,
        Dataflow::WeightStationary,
        64.0,
        32.0,
    );
    let spec = TraceSpec {
        mean_in: 256.0,
        mean_out: 64.0,
        sigma_in: 0.5,
        sigma_out: 0.4,
        max_len: 16_384,
        shared_prefix_tokens: 0,
    };
    let mut cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
    cfg.max_batch = 16;
    cfg.eval_blocks = 1;
    cfg.ctx_bucket = 256;
    let probe = sim::probe(&model, &hw, &cfg, &spec);
    cfg.slo = probe.slo(3.0, 4.0);
    let n_replicas = 4usize;
    let rate = 0.9 * n_replicas as f64 * probe.capacity_rps();
    let stream = sim::RequestStream::poisson(&spec, rate, 96, 7);
    let fleets = [
        FleetConfig::homogeneous(n_replicas, RouterPolicy::RoundRobin),
        FleetConfig::homogeneous(n_replicas, RouterPolicy::JoinShortestQueue),
        FleetConfig::disaggregated(1, n_replicas - 1, 1e-8),
    ];

    println!(
        "fleet_steady_state: 96 requests @ {:.3} req/s (0.9x fleet capacity), \
         model {}, {} replicas of {}",
        rate,
        model.name,
        n_replicas,
        hw.describe()
    );
    for fleet in &fleets {
        // one cold run for the shape/iteration counts
        let cold = sim::simulate_fleet(&stream, &model, &hw, &cfg, fleet);
        let iters: usize = cold.per_replica.iter().map(|m| m.n_iterations).sum();
        let wall = Bench::new(&format!("fleet_steady_state/{}", fleet.router.name()))
            .budget_ms(2000)
            .run(|| sim::simulate_fleet(&stream, &model, &hw, &cfg, fleet));
        println!(
            "    {:<22} sim {:>9.3}s / wall -> {:>10.1} sim-s per wall-s | \
             {} iterations total | imbalance {:.3} | kv-handoff {} tok",
            fleet.describe(),
            cold.makespan_s,
            cold.makespan_s / wall.max(1e-12),
            iters,
            cold.load_imbalance,
            cold.kv_transfer_tokens,
        );
    }
}

//! Property tests for the continuous-batching serving simulator:
//! conservation (every admitted request eventually completes or is
//! explicitly rejected), clock monotonicity, and bit-identical metrics
//! across repeated runs with the same seed — over randomized streams,
//! strategies and KV budgets.

use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::sim::{self, MappingPolicy, ServingMetrics, SimConfig, SloSpec};
use compass::util::Rng;
use compass::workload::serving::ServingStrategy;
use compass::workload::trace::TraceSpec;
use compass::workload::ModelSpec;

fn tiny_hw() -> HwConfig {
    HwConfig::homogeneous(
        2,
        2,
        ChipletClass::S,
        Dataflow::WeightStationary,
        32.0,
        16.0,
    )
}

fn tiny_spec() -> TraceSpec {
    TraceSpec {
        mean_in: 48.0,
        mean_out: 8.0,
        sigma_in: 0.5,
        sigma_out: 0.4,
        max_len: 4096,
        shared_prefix_tokens: 0,
    }
}

fn cfg_for(strategy: ServingStrategy, kv_tokens: u64) -> SimConfig {
    let mut cfg = SimConfig::new(strategy);
    cfg.policy = MappingPolicy::Pipeline;
    cfg.max_batch = 6;
    cfg.chunk_tokens = 24;
    cfg.kv_budget_tokens = kv_tokens;
    cfg.ctx_bucket = 32;
    cfg.eval_blocks = 1;
    cfg.slo = SloSpec::new(0.5, 0.1);
    cfg.max_iterations = 500_000;
    cfg
}

/// Run one simulation at `rate_scale` x the probe-estimated service
/// capacity (absolute rates are meaningless at tiny-model latencies).
fn run(
    strategy: ServingStrategy,
    kv_tokens: u64,
    rate_scale: f64,
    n: usize,
    seed: u64,
) -> ServingMetrics {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let cfg = cfg_for(strategy, kv_tokens);
    let probe = sim::probe(&model, &hw, &cfg, &tiny_spec());
    let stream =
        sim::RequestStream::poisson(&tiny_spec(), rate_scale * probe.capacity_rps(), n, seed);
    sim::simulate_serving(&stream, &model, &hw, &cfg)
}

/// Conservation: arrived == completed + rejected, for every strategy
/// across randomized seeds, rates and KV budgets (including budgets
/// tight enough to force queue stalls, preemptions and rejections).
#[test]
fn conservation_across_randomized_runs() {
    let mut rng = Rng::seed_from_u64(42);
    for trial in 0..12 {
        let strategy = ServingStrategy::ALL[trial % 3];
        let kv_tokens = *rng.choose(&[4096u64, 512, 160]);
        let rate_scale = 0.3 + rng.gen_f64() * 2.5;
        let n = 6 + rng.gen_index(8);
        let seed = rng.next_u64();
        let m = run(strategy, kv_tokens, rate_scale, n, seed);
        assert_eq!(
            m.n_completed + m.n_rejected,
            m.n_arrived,
            "{strategy:?} kv={kv_tokens} scale={rate_scale} n={n} seed={seed}"
        );
        assert!(
            !m.truncated,
            "iteration cap hit: {strategy:?} kv={kv_tokens}"
        );
    }
}

/// The simulated clock never runs backwards: iterations are ordered,
/// non-negative, and per-request timestamps respect
/// arrival <= first token <= completion.
#[test]
fn clock_monotonicity_and_causality() {
    for strategy in ServingStrategy::ALL {
        let m = run(strategy, 1024, 1.5, 10, 9);
        let mut prev_start = 0.0f64;
        for it in &m.iters {
            assert!(it.end_s >= it.start_s, "{strategy:?}");
            assert!(it.start_s >= prev_start - 1e-12, "{strategy:?}");
            assert!(it.kv_frac <= 1.0 + 1e-9, "{strategy:?}");
            prev_start = it.start_s;
        }
        // TTFT/TPOT samples are non-negative by construction
        assert!(m.ttft.mean >= 0.0 && m.ttft.p99 >= 0.0);
        assert!(m.tpot.mean >= 0.0 && m.tpot.p99 >= 0.0);
        assert!(m.makespan_s >= m.iters.last().map_or(0.0, |i| i.end_s) - 1e-12);
    }
}

/// Bit-identical metrics across repeated runs with the same seed, and
/// different results for a different stream seed.
#[test]
fn metrics_bit_identical_for_same_seed() {
    for strategy in ServingStrategy::ALL {
        let a = run(strategy, 768, 1.2, 9, 21);
        let b = run(strategy, 768, 1.2, 9, 21);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits(), "{strategy:?}");
        assert_eq!(
            a.throughput_tps.to_bits(),
            b.throughput_tps.to_bits(),
            "{strategy:?}"
        );
        assert_eq!(a.ttft.p99.to_bits(), b.ttft.p99.to_bits(), "{strategy:?}");
        assert_eq!(a.tpot.p99.to_bits(), b.tpot.p99.to_bits(), "{strategy:?}");
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits(), "{strategy:?}");
        assert_eq!(a.n_iterations, b.n_iterations, "{strategy:?}");
        assert_eq!(a.n_preemptions, b.n_preemptions, "{strategy:?}");
        assert_eq!(format!("{:?}", a.iters), format!("{:?}", b.iters));
        let c = run(strategy, 768, 1.2, 9, 22);
        assert_ne!(
            a.makespan_s.to_bits(),
            c.makespan_s.to_bits(),
            "{strategy:?} should differ across seeds"
        );
    }
}

/// A KV budget below a request's total footprint rejects it explicitly
/// instead of deadlocking, and the rest of the stream still completes.
#[test]
fn infeasible_requests_are_rejected_not_stuck() {
    // budget of 64 tokens: most ~48-token prompts plus outputs won't fit
    let m = run(ServingStrategy::Orca, 64, 1.0, 12, 3);
    assert_eq!(m.n_completed + m.n_rejected, m.n_arrived);
    assert!(m.n_rejected > 0, "expected rejections under a 64-token budget");
}

/// Preemption path: a budget that admits more optimistic decodes than
/// it can grow still conserves requests and stays within budget.
#[test]
fn preemption_conserves_and_respects_budget() {
    let mut any_preempt = false;
    for seed in [1u64, 2, 3, 4, 5] {
        let m = run(ServingStrategy::ChunkedPrefill, 160, 2.5, 10, seed);
        assert_eq!(m.n_completed + m.n_rejected, m.n_arrived, "seed {seed}");
        for it in &m.iters {
            assert!(it.kv_frac <= 1.0 + 1e-9, "seed {seed}");
        }
        any_preempt |= m.n_preemptions > 0;
    }
    assert!(any_preempt, "no preemption was ever exercised");
}

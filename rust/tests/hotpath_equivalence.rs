//! Bitwise-equivalence properties for the allocation-free hot path and
//! parallel replica stepping (PR 8).
//!
//! The serving-simulator refactor (slab/intrusive-queue scheduler
//! storage, reusable scratch buffers, incremental KV-pressure and
//! front-end counters, heap-backed event queues, parallel
//! `Pool::advance_all`) must not move a single bit anywhere: every
//! metric, per-replica breakdown and per-request timing is compared
//! via `f64::to_bits` between
//!
//! * one worker thread and many (the scoped-thread replica stepping,
//!   in the style of `engine_parallel.rs`'s thread-count invariance);
//! * untraced runs and runs with a recording telemetry sink attached
//!   (buffered per-replica emission must replay the serial byte
//!   stream, so trace JSON is compared byte-for-byte too);
//! * repeated runs of the same configuration (the heap-based event
//!   queues must drain exactly the order of the sorted-`Vec`s they
//!   replaced — equal-timestamp regression tests pin the tie-breaks).
//!
//! Thread counts are driven through `COMPASS_THREADS`, which
//! `Pool::advance_all` reads at pool construction; the env var is
//! process-global, so every mutation here is serialized behind one
//! static mutex and restored afterwards.

use std::sync::Mutex;

use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::sim::{
    self, DrainSpec, FaultSchedule, FleetConfig, Frontend, MappingPolicy, RebalanceSpec,
    ResilienceSpec, RetryPolicy, RouterPolicy, SimConfig, SloSpec, SpanCollector,
};
use compass::util::Rng;
use compass::workload::serving::ServingStrategy;
use compass::workload::trace::TraceSpec;
use compass::workload::ModelSpec;

/// Serializes `COMPASS_THREADS` mutation across the whole test binary.
static ENV_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with the pool thread count pinned to `n`, restoring the
/// previous environment afterwards (even across unwinds the next test
/// re-acquires the lock before reading, so a poisoned guard is fine).
fn with_threads<T>(n: usize, f: impl FnOnce() -> T) -> T {
    let _g = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let old = std::env::var("COMPASS_THREADS").ok();
    std::env::set_var("COMPASS_THREADS", n.to_string());
    let out = f();
    match old {
        Some(v) => std::env::set_var("COMPASS_THREADS", v),
        None => std::env::remove_var("COMPASS_THREADS"),
    }
    out
}

fn tiny_hw() -> HwConfig {
    HwConfig::homogeneous(
        2,
        2,
        ChipletClass::S,
        Dataflow::WeightStationary,
        32.0,
        16.0,
    )
}

fn tiny_spec() -> TraceSpec {
    TraceSpec {
        mean_in: 48.0,
        mean_out: 8.0,
        sigma_in: 0.5,
        sigma_out: 0.4,
        max_len: 4096,
        shared_prefix_tokens: 0,
    }
}

fn cfg_for(strategy: ServingStrategy, kv_tokens: u64) -> SimConfig {
    let mut cfg = SimConfig::new(strategy);
    cfg.policy = MappingPolicy::Pipeline;
    cfg.max_batch = 6;
    cfg.chunk_tokens = 24;
    cfg.kv_budget_tokens = kv_tokens;
    cfg.ctx_bucket = 32;
    cfg.eval_blocks = 1;
    cfg.slo = SloSpec::new(0.5, 0.1);
    cfg.max_iterations = 500_000;
    cfg
}

fn assert_serving_bitwise(a: &sim::ServingMetrics, b: &sim::ServingMetrics, ctx: &str) {
    assert_eq!(a.n_arrived, b.n_arrived, "{ctx}: arrived");
    assert_eq!(a.n_completed, b.n_completed, "{ctx}: completed");
    assert_eq!(a.n_rejected, b.n_rejected, "{ctx}: rejected");
    assert_eq!(a.n_preemptions, b.n_preemptions, "{ctx}: preemptions");
    assert_eq!(a.n_iterations, b.n_iterations, "{ctx}: iterations");
    assert_eq!(a.gen_tokens, b.gen_tokens, "{ctx}: gen tokens");
    assert_eq!(a.distinct_shapes, b.distinct_shapes, "{ctx}: shapes");
    assert_eq!(a.max_queue_depth, b.max_queue_depth, "{ctx}: max queue");
    for (name, x, y) in [
        ("makespan", a.makespan_s, b.makespan_s),
        ("busy", a.busy_s, b.busy_s),
        ("energy", a.energy_pj, b.energy_pj),
        ("ttft p99", a.ttft.p99, b.ttft.p99),
        ("tpot p99", a.tpot.p99, b.tpot.p99),
        ("ttft mean", a.ttft.mean, b.ttft.mean),
        ("tpot mean", a.tpot.mean, b.tpot.mean),
        ("slo goodput", a.slo_goodput_tps, b.slo_goodput_tps),
        ("occupancy", a.mean_batch_occupancy, b.mean_batch_occupancy),
        ("mean queue", a.mean_queue_depth, b.mean_queue_depth),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {name}");
    }
}

/// Per-replica metrics and per-request timings, all via `to_bits`.
fn assert_fleet_bitwise(a: &sim::FleetMetrics, b: &sim::FleetMetrics, ctx: &str) {
    assert_eq!(a.per_replica.len(), b.per_replica.len(), "{ctx}: replicas");
    for (i, (x, y)) in a.per_replica.iter().zip(&b.per_replica).enumerate() {
        assert_serving_bitwise(x, y, &format!("{ctx}: replica {i}"));
    }
    assert_eq!(a.n_arrived, b.n_arrived, "{ctx}: arrived");
    assert_eq!(a.n_completed, b.n_completed, "{ctx}: completed");
    assert_eq!(a.n_rejected, b.n_rejected, "{ctx}: rejected");
    assert_eq!(a.n_shed, b.n_shed, "{ctx}: shed");
    assert_eq!(a.n_rebalanced, b.n_rebalanced, "{ctx}: rebalanced");
    assert_eq!(a.faults.n_failed, b.faults.n_failed, "{ctx}: failed");
    assert_eq!(a.faults.n_retried, b.faults.n_retried, "{ctx}: retried");
    assert_eq!(a.faults.n_lost, b.faults.n_lost, "{ctx}: lost");
    assert_eq!(a.faults.n_drained, b.faults.n_drained, "{ctx}: drained");
    for (name, x, y) in [
        ("makespan", a.makespan_s, b.makespan_s),
        ("energy", a.energy_pj, b.energy_pj),
        ("ttft p99", a.ttft.p99, b.ttft.p99),
        ("tpot p99", a.tpot.p99, b.tpot.p99),
        ("slo goodput", a.slo_goodput_tps, b.slo_goodput_tps),
        ("imbalance", a.load_imbalance, b.load_imbalance),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {name}");
    }
    assert_eq!(a.outcomes.len(), b.outcomes.len(), "{ctx}: outcomes");
    for (i, (x, y)) in a.outcomes.iter().zip(&b.outcomes).enumerate() {
        assert_eq!(
            x.arrival_s.to_bits(),
            y.arrival_s.to_bits(),
            "{ctx}: outcome {i} arrival"
        );
        assert_eq!(
            x.first_token_s.map(f64::to_bits),
            y.first_token_s.map(f64::to_bits),
            "{ctx}: outcome {i} first token"
        );
        assert_eq!(
            x.finish_s.map(f64::to_bits),
            y.finish_s.map(f64::to_bits),
            "{ctx}: outcome {i} finish"
        );
        assert_eq!(x.rejected, y.rejected, "{ctx}: outcome {i} rejected");
    }
}

fn stream_for(rate_scale: f64, n: usize, seed: u64, cfg: &SimConfig) -> sim::RequestStream {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let probe = sim::probe(&model, &hw, cfg, &tiny_spec());
    sim::RequestStream::poisson(&tiny_spec(), rate_scale * probe.capacity_rps(), n, seed)
}

/// Randomized single-replica sweep across all three serving strategies
/// and tight/ample KV budgets: the arena/intrusive-queue scheduler must
/// reproduce itself exactly run over run (preemption storms included),
/// and the debug cross-checks against the old full rescans run inside.
#[test]
fn serving_hot_path_is_deterministic_across_strategies() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let mut rng = Rng::seed_from_u64(0xC0FFEE);
    for strategy in [
        ServingStrategy::Vllm,
        ServingStrategy::Orca,
        ServingStrategy::ChunkedPrefill,
    ] {
        for case in 0..4 {
            // tight budgets exercise the incremental eviction loop
            let kv = [384, 1024, 4096, 640][case];
            let cfg = cfg_for(strategy, kv);
            let n = 8 + rng.gen_index(10);
            let seed = rng.next_u64();
            let scale = 1.0 + rng.gen_f64() * 2.0;
            let stream = stream_for(scale, n, seed, &cfg);
            let a = sim::simulate_serving(&stream, &model, &hw, &cfg);
            let b = sim::simulate_serving(&stream, &model, &hw, &cfg);
            assert_serving_bitwise(&a, &b, &format!("{strategy:?} case {case}"));
            assert_eq!(
                a.n_completed + a.n_rejected + a.n_in_flight,
                a.n_arrived,
                "{strategy:?} case {case}: conservation"
            );
        }
    }
}

/// The acceptance property: `simulate_fleet_frontend` on 1 thread vs 4
/// threads, bitwise, across homogeneous (JSQ and round-robin),
/// rebalancing and disaggregated shapes on randomized streams.
#[test]
fn fleet_frontend_bitwise_equal_on_one_and_many_threads() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let cfg = cfg_for(ServingStrategy::ChunkedPrefill, 1024);
    let mut rng = Rng::seed_from_u64(0xFEED);
    let shapes = [
        FleetConfig::homogeneous(4, RouterPolicy::JoinShortestQueue),
        FleetConfig::homogeneous(3, RouterPolicy::RoundRobin),
        FleetConfig::disaggregated(1, 3, 1e-7),
    ];
    for (si, fleet) in shapes.iter().enumerate() {
        for case in 0..2 {
            let n = 12 + rng.gen_index(10);
            let seed = rng.next_u64();
            let stream = stream_for(2.0 + rng.gen_f64(), n, seed, &cfg);
            let hws = vec![hw.clone(); fleet.total_replicas()];
            let fe = if case == 0 {
                Frontend::baseline()
            } else {
                Frontend::baseline().with_rebalance(RebalanceSpec::new(0.2, 1e-7))
            };
            let serial = with_threads(1, || {
                sim::simulate_fleet_frontend(&stream, &model, &hws, &cfg, fleet, &fe)
            });
            let parallel = with_threads(4, || {
                sim::simulate_fleet_frontend(&stream, &model, &hws, &cfg, fleet, &fe)
            });
            assert_fleet_bitwise(
                &serial,
                &parallel,
                &format!("shape {si} case {case} ({})", fleet.describe()),
            );
        }
    }
}

/// Sink-on runs: with a recording collector attached, 1-thread and
/// 4-thread runs must produce byte-identical Chrome-trace JSON (the
/// per-replica `BufferSink` replay reproduces the serial emission
/// order exactly) and metrics bitwise-equal to the untraced run.
#[test]
fn traced_fleet_runs_replay_the_serial_byte_stream() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let cfg = cfg_for(ServingStrategy::ChunkedPrefill, 1024);
    let stream = stream_for(2.2, 16, 77, &cfg);
    for fleet in [
        FleetConfig::homogeneous(3, RouterPolicy::JoinShortestQueue),
        FleetConfig::disaggregated(1, 2, 1e-7),
    ] {
        let hws = vec![hw.clone(); fleet.total_replicas()];
        let fe = Frontend::baseline();
        let plain = with_threads(4, || {
            sim::simulate_fleet_frontend(&stream, &model, &hws, &cfg, &fleet, &fe)
        });
        let run_traced = |threads: usize| {
            with_threads(threads, || {
                let c = SpanCollector::shared();
                let sink: sim::SharedSink = c.clone();
                let m = sim::simulate_fleet_frontend_traced(
                    &stream, &model, &hws, &cfg, &fleet, &fe, &sink,
                );
                let json = c.lock().unwrap().chrome_trace_json();
                (m, json)
            })
        };
        let (m1, j1) = run_traced(1);
        let (m4, j4) = run_traced(4);
        assert_fleet_bitwise(&plain, &m1, &format!("{}: untraced vs 1t", fleet.describe()));
        assert_fleet_bitwise(&m1, &m4, &format!("{}: 1t vs 4t traced", fleet.describe()));
        assert_eq!(
            j1, j4,
            "{}: trace JSON differs between 1 and 4 threads",
            fleet.describe()
        );
        assert!(!j1.is_empty() && j1.starts_with("{\"traceEvents\":["));
    }
}

/// Fault injection end-to-end on 1 vs 4 threads: seeded crash +
/// straggler storms with failover, capped-backoff retries and
/// proactive drain, untraced and with a sink (byte-compared).
#[test]
fn fleet_faults_bitwise_equal_on_one_and_many_threads() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let cfg = cfg_for(ServingStrategy::ChunkedPrefill, 1024);
    let mut rng = Rng::seed_from_u64(0xFA_07);
    for case in 0..3 {
        let n = 14 + rng.gen_index(8);
        let seed = rng.next_u64();
        let stream = stream_for(2.5, n, seed, &cfg);
        let fleet = FleetConfig::homogeneous(3, RouterPolicy::JoinShortestQueue);
        let hws = vec![hw.clone(); 3];
        let fe = Frontend::baseline().with_rebalance(RebalanceSpec::new(0.4, 1e-7));
        let horizon = stream.horizon_s();
        let schedule = FaultSchedule::seeded(3, horizon, 2, 1, 11 + case as u64);
        let res = ResilienceSpec::none()
            .with_schedule(schedule)
            .with_retry(RetryPolicy::capped(2, 0.05 * horizon, 0.2 * horizon))
            .with_drain(DrainSpec::new(0.05 * horizon, 1e-7, 4))
            .with_failover(case != 1);
        let serial = with_threads(1, || {
            sim::simulate_fleet_faults(&stream, &model, &hws, &cfg, &fleet, &fe, &res)
        });
        let parallel = with_threads(4, || {
            sim::simulate_fleet_faults(&stream, &model, &hws, &cfg, &fleet, &fe, &res)
        });
        assert_fleet_bitwise(&serial, &parallel, &format!("faults case {case}"));
        // sink-on: the buffered replay must also hold under crashes,
        // retries and drains
        let run_traced = |threads: usize| {
            with_threads(threads, || {
                let c = SpanCollector::shared();
                let sink: sim::SharedSink = c.clone();
                let m = sim::simulate_fleet_faults_traced(
                    &stream, &model, &hws, &cfg, &fleet, &fe, &res, &sink,
                );
                let json = c.lock().unwrap().chrome_trace_json();
                (m, json)
            })
        };
        let (m1, j1) = run_traced(1);
        let (m4, j4) = run_traced(4);
        assert_fleet_bitwise(&serial, &m1, &format!("faults case {case}: traced"));
        assert_fleet_bitwise(&m1, &m4, &format!("faults case {case}: traced threads"));
        assert_eq!(j1, j4, "faults case {case}: trace JSON differs across threads");
    }
}

/// Equal-timestamp tie-break regression (the event-heap replacement of
/// the old stable sort): a fault event scheduled at *exactly* an
/// arrival's timestamp must drain before the arrival. A crash on the
/// replica JSQ would pick, timed bit-for-bit at the first arrival,
/// therefore kills nothing (the replica is still empty) and the
/// arrival routes around it via failover — zero request failures. If
/// arrivals drained first, the request would be injected and then
/// crash-killed.
#[test]
fn crash_at_arrival_instant_drains_before_the_arrival() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let cfg = cfg_for(ServingStrategy::ChunkedPrefill, 2048);
    let stream = stream_for(1.5, 12, 5, &cfg);
    let t0 = stream.requests[0].arrival_s;
    let fleet = FleetConfig::homogeneous(2, RouterPolicy::JoinShortestQueue);
    let hws = vec![hw.clone(); 2];
    // both replicas empty at t0 -> JSQ ties to replica 0, the one we crash
    let res = ResilienceSpec::none()
        .with_schedule(FaultSchedule::none().crash(0, t0, 1e-3))
        .with_failover(true);
    let m = with_threads(4, || {
        sim::simulate_fleet_faults(&stream, &model, &hws, &cfg, &fleet, &Frontend::baseline(), &res)
    });
    assert_eq!(m.faults.n_crashes, 1);
    assert_eq!(
        m.faults.n_failed, 0,
        "the crash drained after the tied arrival: a request was killed"
    );
    assert_eq!(m.n_completed + m.n_rejected, m.n_arrived);
}

/// Equal-timestamp faults drain in schedule order (the old stable
/// sort's FIFO at ties): two straggler windows on the same replica at
/// the same instant — the later-scheduled one overwrites, so the run
/// is bitwise-identical to scheduling only the winner.
#[test]
fn equal_time_stragglers_apply_in_schedule_order() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let cfg = cfg_for(ServingStrategy::ChunkedPrefill, 1024);
    let stream = stream_for(2.0, 14, 23, &cfg);
    let fleet = FleetConfig::homogeneous(2, RouterPolicy::JoinShortestQueue);
    let hws = vec![hw.clone(); 2];
    let horizon = stream.horizon_s();
    let t = 0.3 * horizon;
    let a = (0.4 * horizon, 8.0); // (duration, slowdown) of fault A
    let b = (0.2 * horizon, 2.0);
    let run = |schedule: FaultSchedule| {
        let res = ResilienceSpec::none().with_schedule(schedule);
        with_threads(4, || {
            sim::simulate_fleet_faults(&stream, &model, &hws, &cfg, &fleet, &Frontend::baseline(), &res)
        })
    };
    let ab = run(FaultSchedule::none()
        .straggler(0, t, a.0, a.1)
        .straggler(0, t, b.0, b.1));
    let only_b = run(FaultSchedule::none().straggler(0, t, b.0, b.1));
    assert_fleet_bitwise(&ab, &only_b, "A-then-B must equal B alone");
    let ba = run(FaultSchedule::none()
        .straggler(0, t, b.0, b.1)
        .straggler(0, t, a.0, a.1));
    let only_a = run(FaultSchedule::none().straggler(0, t, a.0, a.1));
    assert_fleet_bitwise(&ba, &only_a, "B-then-A must equal A alone");
}

/// Thread-count invariance holds for *any* worker count, not just the
/// 1-vs-4 anchor: sweep 2, 3 and 8 workers over one fixed scenario.
#[test]
fn thread_count_sweep_is_invariant() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let cfg = cfg_for(ServingStrategy::ChunkedPrefill, 1024);
    let stream = stream_for(2.4, 18, 41, &cfg);
    let fleet = FleetConfig::homogeneous(4, RouterPolicy::JoinShortestQueue);
    let hws = vec![hw.clone(); 4];
    let fe = Frontend::baseline().with_rebalance(RebalanceSpec::new(0.3, 1e-7));
    let anchor = with_threads(1, || {
        sim::simulate_fleet_frontend(&stream, &model, &hws, &cfg, &fleet, &fe)
    });
    for threads in [2usize, 3, 8] {
        let m = with_threads(threads, || {
            sim::simulate_fleet_frontend(&stream, &model, &hws, &cfg, &fleet, &fe)
        });
        assert_fleet_bitwise(&anchor, &m, &format!("{threads} threads"));
    }
}

//! Paper §IV / Fig. 5: the mapping encoding expresses data, model, and
//! pipeline parallelism as special cases (Algorithm 1). This example
//! instantiates all three on the same workload/hardware, evaluates them
//! with the Compass engine, renders their spatio-temporal diagrams, and
//! shows that the GA finds a hybrid at least as good as every preset.
//!
//! Run: `cargo run --release --example parallelism_explorer`

use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::cost::{Evaluator, SimOptions};
use compass::ga::{self, GaConfig};
use compass::mapping::presets;
use compass::report::ascii_timeline;
use compass::workload::{build_workload, ModelSpec, Request, WorkloadParams};

fn main() {
    let model = ModelSpec::gpt3_7b();
    let batch: Vec<Request> = (0..8)
        .map(|i| Request::prefill(64 + 96 * (i % 4) as u64)) // variable lens
        .collect();
    let params = WorkloadParams {
        micro_batch_size: 2,
        tensor_parallel: 2,
        eval_blocks: 1,
    };
    let w = build_workload(&model, &batch, &params);
    let hw = HwConfig::homogeneous(2, 2, ChipletClass::M, Dataflow::WeightStationary, 64.0, 32.0);
    let ev = Evaluator {
        opts: SimOptions {
            record_timeline: true,
            ..Default::default()
        },
    };
    let chips = hw.num_chiplets();
    let rows = w.num_micro_batches();
    let cols = w.layers_per_mb;

    println!(
        "workload: {} | {} micro-batches x {} layers -> {} chiplets\n",
        model.name, rows, cols, chips
    );

    let presets = [
        ("data parallelism", presets::data_parallel(rows, cols, chips)),
        (
            "pipeline parallelism",
            presets::pipeline_parallel(rows, cols, chips),
        ),
        ("model parallelism", {
            let mp = presets::model_parallel(cols, chips);
            let mut m = compass::mapping::Mapping::new(rows, cols);
            for mb in 0..rows {
                for l in 0..cols {
                    m.set_chip(mb, l, mp.chip(0, l));
                }
            }
            m
        }),
    ];

    let mut best_preset = f64::INFINITY;
    for (name, mapping) in &presets {
        let r = ev.eval_batch(&w, &hw, mapping);
        let edp = r.latency_cycles * r.energy_pj;
        best_preset = best_preset.min(edp);
        println!(
            "=== {name}: latency {:.3e} cyc, energy {:.3e} pJ, L*E {:.3e}",
            r.latency_cycles, r.energy_pj, edp
        );
        println!(
            "{}",
            ascii_timeline(r.timeline.as_deref().unwrap_or(&[]), chips, 80)
        );
    }

    // GA hybrid search over the same space, through the batched
    // multi-threaded evaluation engine
    let mev = compass::cost::engine::MappingEvaluator::new(&w, &hw);
    let res = ga::search(rows, cols, chips, &GaConfig::reduced(), &mev);
    let r = ev.eval_batch(&w, &hw, &res.best);
    println!(
        "=== GA hybrid: latency {:.3e} cyc, energy {:.3e} pJ, L*E {:.3e} ({:+.1}% vs best preset)",
        r.latency_cycles,
        r.energy_pj,
        res.best_fitness,
        100.0 * (res.best_fitness - best_preset) / best_preset
    );
    println!(
        "{}",
        ascii_timeline(r.timeline.as_deref().unwrap_or(&[]), chips, 80)
    );
    assert!(
        res.best_fitness <= best_preset * 1.0001,
        "GA must match or beat the parallelism presets"
    );
    println!("GA hybrid matches or beats every Algorithm-1 preset  [ok]");
}

//! End-to-end integration: full Compass runs (trace -> scenario ->
//! co-exploration -> evaluation) at test-sized budgets, the baselines on
//! the same footing, and the paper's qualitative claims as assertions.

use compass::arch::HwSpace;
use compass::baselines::{fixed_length_scenario, gemini, moham, random, scar};
use compass::bo::NativeGp;
use compass::dse::{self, DseConfig};
use compass::experiments as exp;
use compass::ga::GaConfig;
use compass::workload::serving::{Scenario, ServingStrategy};
use compass::workload::trace::{Trace, TraceSpec};
use compass::workload::ModelSpec;

fn test_cfg() -> DseConfig {
    let mut cfg = DseConfig::reduced();
    cfg.ga = GaConfig {
        population: 8,
        generations: 5,
        ..GaConfig::tiny()
    };
    cfg.bo.rounds = 7;
    cfg.bo.init = 4;
    cfg.eval_blocks = 1;
    cfg
}

#[test]
fn compass_full_pipeline_on_sharegpt_decode() {
    let scene = exp::Scene::new("sharegpt", false, 64.0);
    let (scenario, test_scenario, _, model) = scene.build(3);
    let space = scene.space();
    let cfg = test_cfg();
    let mut gp = NativeGp::new();
    let out = dse::compass_dse(&scenario, &model, &space, &cfg, &mut gp);
    assert!(out.eval.total_cost() > 0.0);
    // transfers to the held-out test trace
    let test = dse::search_mappings(&test_scenario, &model, &out.hw, &cfg.ga, cfg.eval_blocks);
    assert!(test.eval.latency_cycles > 0.0);
    assert!(test.eval.total_cost().is_finite());
}

#[test]
fn compass_competitive_with_random_hardware_at_equal_budget() {
    let scene = exp::Scene::new("sharegpt", false, 64.0);
    let (scenario, _, _, model) = scene.build(5);
    let space = scene.space();
    let cfg = test_cfg();
    let mut gp = NativeGp::new();
    let out = dse::compass_dse(&scenario, &model, &space, &cfg, &mut gp);
    let (_, rand_obj) = random::random_hardware(&space, &cfg.bo, |hw| {
        dse::search_mappings(&scenario, &model, hw, &cfg.ga, cfg.eval_blocks)
            .eval
            .total_cost()
    });
    assert!(
        out.eval.total_cost() <= rand_obj * 1.5,
        "BO {:.3e} vs random {:.3e}",
        out.eval.total_cost(),
        rand_obj
    );
}

#[test]
fn ga_mapping_beats_random_and_competes_with_scar() {
    let trace = Trace::new(&TraceSpec::sharegpt(), 128, 9);
    let scen = Scenario::decode(&trace, 32, 1);
    let model = ModelSpec::gpt3_7b();
    let hw = compass::arch::HwConfig::homogeneous(
        2,
        4,
        compass::arch::ChipletClass::M,
        compass::arch::Dataflow::WeightStationary,
        64.0,
        32.0,
    );
    let ga_cfg = GaConfig {
        population: 12,
        generations: 10,
        ..GaConfig::reduced()
    };
    let ga = dse::search_mappings(&scen, &model, &hw, &ga_cfg, 1);
    let rand = random::random_mappings(&scen, &model, &hw, &ga_cfg, 1);
    let scar_ms = scar::scar_mappings(&scen, &model, &hw, 1);
    let edp = |e: &compass::cost::EvalResult| e.latency_cycles * e.energy_pj;
    assert!(
        edp(&ga.eval) <= edp(&rand.eval) * 1.001,
        "GA {:.3e} must beat random {:.3e}",
        edp(&ga.eval),
        edp(&rand.eval)
    );
    assert!(
        edp(&ga.eval) <= edp(&scar_ms.eval) * 1.05,
        "GA {:.3e} must be competitive with SCAR {:.3e}",
        edp(&ga.eval),
        edp(&scar_ms.eval)
    );
}

#[test]
fn gemini_fixed_length_view_transfers_worse_than_direct_search() {
    // the paper's core claim: padding to a fixed length misguides the
    // search when the true batch is highly variable
    let trace = Trace::new(&TraceSpec::sharegpt(), 256, 21);
    let scen = Scenario::decode(&trace, 64, 1);
    let fixed = fixed_length_scenario(&scen, &trace);
    let model = ModelSpec::gpt3_7b();
    let hw = compass::arch::HwConfig::homogeneous(
        2,
        4,
        compass::arch::ChipletClass::M,
        compass::arch::Dataflow::WeightStationary,
        64.0,
        32.0,
    );
    let sa = gemini::SaConfig {
        iterations: 60,
        t0: 1.0,
        seed: 2,
    };
    let padded = gemini::gemini_mappings(&fixed, &model, &hw, &sa, 1);
    let transferred = gemini::reevaluate(&scen, &model, &hw, &padded.mappings, 1);
    let direct = gemini::gemini_mappings(&scen, &model, &hw, &sa, 1);
    assert!(
        direct.eval.latency_cycles * direct.eval.energy_pj
            <= transferred.latency_cycles * transferred.energy_pj * 1.2,
        "direct search should not lose to the padded-view transfer"
    );
}

#[test]
fn moham_restriction_costs_energy_on_batched_decode() {
    // forcing micro_batch_size = 1 (independent models) forfeits the
    // merged QKV/FFN GEMMs -> more weight traffic on decode batches
    let trace = Trace::new(&TraceSpec::sharegpt(), 128, 33);
    let scen = Scenario::decode(&trace, 32, 1);
    let model = ModelSpec::gpt3_7b();
    let space = HwSpace::paper(64.0);
    let cfg = test_cfg();
    let mut gp = NativeGp::new();
    let compass = dse::compass_dse(&scen, &model, &space, &cfg, &mut gp);
    let (_, mo) = moham::moham_dse(&scen, &model, &space, &cfg.ga, cfg.eval_blocks);
    assert!(
        compass.eval.energy_pj < mo.eval.energy_pj,
        "compass {:.3e} pJ must beat moham {:.3e} pJ on batched decode",
        compass.eval.energy_pj,
        mo.eval.energy_pj
    );
}

#[test]
fn chunked_prefill_balances_batch_latencies() {
    let trace = Trace::new(&TraceSpec::govreport(), 128, 17);
    let model = ModelSpec::tiny();
    let hw = compass::arch::HwConfig::homogeneous(
        2,
        2,
        compass::arch::ChipletClass::S,
        compass::arch::Dataflow::WeightStationary,
        32.0,
        16.0,
    );
    let ga = GaConfig::tiny();
    let imbalance = |strategy| {
        let scen = Scenario::serving(strategy, &trace, 8192, 16, 3, 2048);
        let ms = dse::search_mappings(&scen, &model, &hw, &ga, 1);
        let ls: Vec<f64> = ms.eval.per_group.iter().map(|g| g.0).collect();
        let max = ls.iter().cloned().fold(0.0, f64::max);
        let min = ls.iter().cloned().fold(f64::INFINITY, f64::min);
        max / min
    };
    let vllm = imbalance(ServingStrategy::Vllm);
    let chunked = imbalance(ServingStrategy::ChunkedPrefill);
    assert!(
        chunked < vllm,
        "chunked prefill must even out batches: chunked {chunked:.1}x vs vllm {vllm:.1}x"
    );
}

#[test]
fn table1_probe_matches_paper_sign_pattern() {
    let t = exp::table1(64.0);
    let parse = |s: &str| s.trim_end_matches('x').parse::<f64>().unwrap();
    // every phase: WS superior at 128, OS superior at 10240
    for col in 1..=4 {
        assert!(parse(&t.rows[0][col]) > 1.0, "col {col} @128");
        assert!(parse(&t.rows[3][col]) < 1.0, "col {col} @10240");
    }
    // QK^T flips earliest
    assert!(parse(&t.rows[2][2]) < parse(&t.rows[2][1]));
}

#[test]
fn validation_errors_stay_small() {
    let t = exp::table5(1);
    for cell in &t.rows[2][2..] {
        let v: f64 = cell.trim_end_matches('%').parse().unwrap();
        assert!(v < 15.0, "Table V error {cell} exceeds 15%");
    }
}
